//! Bench: Fig. 15 — structured vs unstructured (EIE) FC speedups, with
//! the VGGFC6 folding dip, plus the end-to-end simulated FC layer.

use apu::compiler::emit::{compile_packed_layers, synthetic_packed_network};
use apu::figures;
use apu::sim::{Apu, ApuConfig};
use apu::util::bench::{bench, budget};

fn main() {
    println!("{}", figures::fig15().unwrap().render());

    // Functional cycle-accurate run of a full 4000×4000 structured layer on
    // the Fig. 9 machine (the §4.3 "single layer processing at 400 cycles").
    let layers = synthetic_packed_network(&[4000, 4000], 10, 4, 3).unwrap();
    let program = compile_packed_layers("fc4000", &layers, 0.1, 4, 10).unwrap();
    let mut apu = Apu::new(ApuConfig::default());
    apu.load(&program).unwrap();
    let input: Vec<f32> = (0..4000).map(|i| ((i % 15) as f32 - 7.0) * 0.05).collect();
    apu.run(&input).unwrap();
    let st = apu.stats().clone();
    println!(
        "fc4000 single-layer: {} compute cycles/PE wave (paper: 400), {} route, {} host",
        st.compute_cycles, st.route_cycles, st.host_cycles
    );
    let r = bench("fig15/simulate_fc4000_10pe", budget(), || {
        apu.run(&input).unwrap().len()
    });
    println!("{}", r.report());
    let macs_per_iter = 4000.0 * 4000.0 / 10.0;
    println!("  simulator speed: {:.1} M MACs/s", r.per_second(macs_per_iter) / 1e6);
}
