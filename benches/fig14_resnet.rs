//! Bench: Fig. 14 — ResNet-50 per-layer speedup + utilization.

use apu::compiler::cost::{cost_network, CostModel};
use apu::figures;
use apu::nn::zoo;
use apu::util::bench::{bench, budget};

fn main() {
    println!("{}", figures::fig14().unwrap().render());
    let (_, _, best, util) = figures::fig13_14_summary().unwrap();
    println!("best conv speedup {best:.1}x, mean conv utilization {:.1}%", util * 100.0);
    let net = zoo::resnet50(true);
    let model = CostModel::paper_9pe();
    let r = bench("fig14/cost_resnet50", budget(), || cost_network(&model, &net).unwrap().total_cycles());
    println!("{}", r.report());
}
