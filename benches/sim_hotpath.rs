//! Bench: the simulator + coordinator hot paths (the §Perf targets).
//! Not a paper figure — this is the performance-optimization harness.
//!
//! `--json <path>` writes the results as a machine-readable report
//! (via `util::bench::write_report`) so CI can track the perf trajectory.

use apu::compiler::emit::{compile_packed_layers, synthetic_packed_network};
use apu::pruning::Quantizer;
use apu::sim::{Apu, ApuConfig, ExecOptions};
use apu::util::bench::{bench, budget, write_report, BenchResult};

fn main() {
    let json_path = json_arg();
    let mut results: Vec<BenchResult> = Vec::new();

    // LeNet-class network (the e2e artifact shape).
    let layers = synthetic_packed_network(&[800, 300, 100, 10], 10, 4, 7).unwrap();
    let program = compile_packed_layers("lenet-shape", &layers, 0.15, 4, 10).unwrap();
    let mut apu = Apu::new(ApuConfig::default());
    apu.load(&program).unwrap();
    assert!(apu.is_planned(), "lenet-shape should take the planned path");
    let input: Vec<f32> = (0..800).map(|i| ((i % 15) as f32 - 7.0) * 0.1).collect();

    let r = bench("sim/lenet_inference", budget(), || apu.run(&input).unwrap()[0]);
    println!("{}", r.report());
    let cycles = apu.stats().total_cycles() as f64 / apu.stats().inferences as f64;
    println!("  {:.0} sim cycles/inference -> {:.1} M sim-cycles/s", cycles, r.per_second(cycles) / 1e6);
    let macs = apu.stats().macs as f64 / apu.stats().inferences as f64;
    println!("  {:.1} M MACs/s simulated", r.per_second(macs) / 1e6);
    results.push(r);

    // Same network through the batched executor: one plan walk per layer-step,
    // 32 inferences per call. ns/iter here divided by 32 is the per-inference cost.
    let batch: Vec<&[f32]> = vec![input.as_slice(); 32];
    let r = bench("sim/lenet_inference_batch32", budget(), || apu.run_batch(&batch).unwrap().len());
    println!("{}", r.report());
    println!("  {:.0} ns/inference amortized over batch of 32", r.mean_ns / 32.0);
    results.push(r);

    // The headline scoreboard: the same batch across lane-pool widths.
    // Outputs are bitwise identical at every width — only wall clock moves.
    let mut t1_ns = 0.0;
    for threads in [1usize, 2, 4] {
        apu.set_threads(threads);
        let r = bench(&format!("sim/lenet_inference_batch32_t{threads}"), budget(), || {
            apu.run_batch(&batch).unwrap().len()
        });
        println!("{}", r.report());
        if threads == 1 {
            t1_ns = r.mean_ns;
        } else if t1_ns > 0.0 {
            println!("  {:.2}x vs 1 thread", t1_ns / r.mean_ns);
        }
        results.push(r);
    }

    // The pre-PR-9 lane-major kernel (per-lane weight re-streaming), single
    // thread — the baseline the batch-major weight-stationary kernel beats.
    apu.set_exec_options(ExecOptions { threads: 1, lane_major_kernel: true });
    let r = bench("sim/lenet_inference_batch32_lane_major_kernel", budget(), || {
        apu.run_batch(&batch).unwrap().len()
    });
    println!("{}", r.report());
    if t1_ns > 0.0 {
        println!("  batch-major kernel is {:.2}x vs this lane-major baseline", r.mean_ns / t1_ns);
    }
    results.push(r);
    apu.set_exec_options(ExecOptions::default());

    // big-block single layer (PE inner loop dominated)
    let layers = synthetic_packed_network(&[4000, 4000], 10, 4, 3).unwrap();
    let program = compile_packed_layers("fc4000", &layers, 0.1, 4, 10).unwrap();
    let mut apu = Apu::new(ApuConfig::default());
    apu.load(&program).unwrap();
    let big: Vec<f32> = (0..4000).map(|i| ((i % 15) as f32 - 7.0) * 0.05).collect();
    let r = bench("sim/fc4000_inference", budget(), || apu.run(&big).unwrap()[0]);
    println!("{}", r.report());
    println!("  {:.1} M MACs/s simulated", r.per_second(1_600_000.0) / 1e6);
    results.push(r);

    // quantizer kernel: scalar call per value vs. the vectorized slice path
    let q = Quantizer::new(4, 0.1);
    let xs: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.37).sin()).collect();
    let r = bench("quant/4096_values", budget(), || xs.iter().map(|&x| q.fake(x)).sum::<f32>());
    println!("{}", r.report());
    println!("  {:.1} M quants/s", r.per_second(4096.0) / 1e6);
    results.push(r);

    let mut buf = xs.clone();
    let r = bench("quant/4096_values_slice", budget(), || {
        buf.copy_from_slice(&xs);
        q.fake_slice(&mut buf);
        buf[0]
    });
    println!("{}", r.report());
    println!("  {:.1} M quants/s (slice path, incl. refill copy)", r.per_second(4096.0) / 1e6);
    results.push(r);

    if let Some(path) = json_path {
        write_report(&path, &results).unwrap();
        println!("wrote {path}");
    }
}

fn json_arg() -> Option<String> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--json" {
            return Some(args.next().expect("--json requires a path"));
        }
    }
    None
}
