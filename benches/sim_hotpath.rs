//! Bench: the simulator + coordinator hot paths (the §Perf targets).
//! Not a paper figure — this is the performance-optimization harness.

use apu::compiler::emit::{compile_packed_layers, synthetic_packed_network};
use apu::pruning::Quantizer;
use apu::sim::{Apu, ApuConfig};
use apu::util::bench::{bench, budget};

fn main() {
    // LeNet-class network (the e2e artifact shape).
    let layers = synthetic_packed_network(&[800, 300, 100, 10], 10, 4, 7).unwrap();
    let program = compile_packed_layers("lenet-shape", &layers, 0.15, 4, 10).unwrap();
    let mut apu = Apu::new(ApuConfig::default());
    apu.load(&program).unwrap();
    let input: Vec<f32> = (0..800).map(|i| ((i % 15) as f32 - 7.0) * 0.1).collect();

    let r = bench("sim/lenet_inference", budget(), || apu.run(&input).unwrap()[0]);
    println!("{}", r.report());
    let cycles = apu.stats().total_cycles() as f64 / apu.stats().inferences as f64;
    println!("  {:.0} sim cycles/inference -> {:.1} M sim-cycles/s", cycles, r.per_second(cycles) / 1e6);
    let macs = apu.stats().macs as f64 / apu.stats().inferences as f64;
    println!("  {:.1} M MACs/s simulated", r.per_second(macs) / 1e6);

    // big-block single layer (PE inner loop dominated)
    let layers = synthetic_packed_network(&[4000, 4000], 10, 4, 3).unwrap();
    let program = compile_packed_layers("fc4000", &layers, 0.1, 4, 10).unwrap();
    let mut apu = Apu::new(ApuConfig::default());
    apu.load(&program).unwrap();
    let big: Vec<f32> = (0..4000).map(|i| ((i % 15) as f32 - 7.0) * 0.05).collect();
    let r = bench("sim/fc4000_inference", budget(), || apu.run(&big).unwrap()[0]);
    println!("{}", r.report());
    println!("  {:.1} M MACs/s simulated", r.per_second(1_600_000.0) / 1e6);

    // quantizer kernel
    let q = Quantizer::new(4, 0.1);
    let xs: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.37).sin()).collect();
    let r = bench("quant/4096_values", budget(), || xs.iter().map(|&x| q.fake(x)).sum::<f32>());
    println!("{}", r.report());
    println!("  {:.1} M quants/s", r.per_second(4096.0) / 1e6);
}
