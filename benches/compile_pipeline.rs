//! Bench: end-to-end compile latency of `compiler::pipeline` for the zoo
//! networks — compile throughput is a serving-path concern once fleets
//! hot-load models. Big ImageNet-scale networks run the analysis passes
//! (normalize → map → cost); the executable-scale networks additionally
//! run full emission (pruning, routing schedules, instruction stream).

use apu::compiler::pipeline::{analyze, compile_network, PipelineOptions};
use apu::compiler::CostModel;
use apu::nn::zoo;
use apu::util::bench::{bench, budget};

fn main() {
    let paper = CostModel::paper_9pe();
    let nano = CostModel::nano_4pe();

    // Analysis passes only (emission would exceed the route budget).
    for net in [zoo::alexnet(), zoo::vgg19(true), zoo::resnet50(true), zoo::transformer_mha(8, 512, 64)] {
        let r = bench(&format!("pipeline/analyze/{}", net.name), budget(), || {
            analyze(&net, &paper).unwrap().cost.total_cycles()
        });
        println!("{}", r.report());
    }

    // Full compile (normalize → weights → lower → emit) on executable
    // nets — alexnet-nano exercises the §4.4.3-II tiled emission path
    // (per-tile waves + runtime FoldAdd partial-sum buffers).
    let opts = PipelineOptions::default();
    for (net, model) in
        [(zoo::vgg_nano(), &nano), (zoo::alexnet_nano(), &nano), (zoo::lenet_300_100(), &paper)]
    {
        let r = bench(&format!("pipeline/emit/{}", net.name), budget(), || {
            compile_network(&net, model, &opts).unwrap().program.insns.len()
        });
        println!(
            "{}  ({:.1} compiles/s)",
            r.report(),
            r.per_second(1.0)
        );
    }
}
