//! Bench: Figs. 10/11 — the DSE sweeps (block size and precision).

use apu::figures;
use apu::generator::{sweep_block_size, sweep_precision};
use apu::util::bench::{bench, budget};

fn main() {
    println!("{}", figures::fig10_11_block().unwrap().render());
    println!("{}", figures::fig10_11_precision().unwrap().render());
    let r = bench("fig10_11/full_sweep", budget(), || {
        let a = sweep_block_size(&[200, 400, 800, 1024, 1600, 2048], 4).unwrap();
        let b = sweep_precision(&[4, 8, 16]).unwrap();
        a.len() + b.len()
    });
    println!("{}", r.report());
}
