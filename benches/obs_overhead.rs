//! Bench: what does observability cost? Three layers, three price tags:
//!
//! 1. Metrics hot path — `Counter::inc` / `Histogram::observe` on a
//!    pre-registered handle (the fleet's per-request cost) vs. going
//!    through the registry lookup every time.
//! 2. Trace recording — appending a completed span to a `Tracer`.
//! 3. Simulator profiling — a full inference on the same machine with
//!    and without `enable_profiling`, the number that decides whether
//!    `apu profile` can be left on in CI.

use apu::compiler::{compile_packed_layers, synthetic_packed_network};
use apu::obs::{Registry, Tracer};
use apu::sim::{Apu, ApuConfig};
use apu::util::bench::{bench, budget};

fn main() {
    let b = budget();

    // 1) Metrics hot path.
    let reg = Registry::new();
    let c = reg.counter("bench_ops_total", "bench counter", &[("lane", "hot")]);
    let r = bench("counter.inc (pre-registered handle)", b, || c.inc());
    println!("{}", r.report());
    let r = bench("registry.counter lookup + inc", b, || {
        reg.counter("bench_ops_total", "bench counter", &[("lane", "hot")]).inc()
    });
    println!("{}", r.report());
    let h = reg.histogram(
        "bench_latency_us",
        "bench histogram",
        &apu::obs::metrics::latency_buckets_us(),
        &[],
    );
    let mut x = 0u64;
    let r = bench("histogram.observe", b, || {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
        h.observe((x % 100_000) as f64)
    });
    println!("{}", r.report());

    // 2) Trace recording (tracer swapped out periodically so the event
    //    buffer doesn't grow without bound during the measurement).
    let mut tracer = Tracer::new();
    let r = bench("tracer.end_span", b, || {
        if tracer.len() >= 100_000 {
            tracer = Tracer::new();
        }
        tracer.end_span("op", "bench", 0, 0, 0.0, Vec::new());
    });
    println!("{}", r.report());

    // 3) Profiled vs. unprofiled inference. Both lanes reset stats each
    //    iteration (bounds the profile's record buffer; same work on
    //    both sides so the delta is the mirroring cost alone).
    let layers = synthetic_packed_network(&[64, 40, 12], 4, 4, 99).unwrap();
    let program = compile_packed_layers("obs-bench", &layers, 0.15, 4, 4).unwrap();
    let input: Vec<f32> = (0..64).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();

    let mut plain = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    plain.load(&program).unwrap();
    let r_plain = bench("sim.run (profiling off)", b, || {
        plain.reset_stats();
        plain.run(&input).unwrap()
    });
    println!("{}", r_plain.report());

    let mut profiled = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    profiled.load(&program).unwrap();
    profiled.enable_profiling();
    let r_prof = bench("sim.run (profiling on)", b, || {
        profiled.reset_stats();
        profiled.run(&input).unwrap()
    });
    println!("{}", r_prof.report());
    println!(
        "profiling overhead: {:+.1}% per inference",
        100.0 * (r_prof.mean_ns - r_plain.mean_ns) / r_plain.mean_ns
    );
}
