//! Bench: fleet scaling + dispatch-policy comparison (the ROADMAP's
//! scale-out story). Two experiments, both self-contained (synthetic
//! packed networks — no `make artifacts` needed):
//!
//! 1. Throughput scaling 1 → 8 shards under a saturating burst
//!    (unbounded queues, join-shortest-queue): aggregate req/s should
//!    grow monotonically 1 → 4 on any multi-core host.
//! 2. Dispatch-policy comparison at 4 shards under a paced Poisson
//!    arrival process with bounded queues: per-policy p50/p95/p99,
//!    rejection rate, and queue depth.
//! 3. Multi-model mix: a two-model catalog fleet (2 shards per model)
//!    under 80/20 skewed traffic — per-model SLO rows plus the shared
//!    plan-cache hit/build counters.
//! 4. Result cache under Zipf-repeated inputs: a catalog fleet with the
//!    request-level cache on, driven from a 64-entry Zipf(1.1) input
//!    pool — reports hit-path vs miss-path latency and emits
//!    `fleet/zipf_cache_{hit,miss}` bench rows.
//!
//! Args (after `cargo bench --bench fleet_scaling --`):
//!   `--json PATH`   merge bench rows into PATH (ci.sh perf trajectory)
//!   `--only cache`  run just the result-cache experiment

use std::sync::Arc;
use std::time::{Duration, Instant};

use apu::compiler::{compile_packed_layers, synthetic_packed_network};
use apu::coordinator::{
    ApuEngine, BatchPolicy, DispatchPolicy, Engine, Fleet, FleetConfig, InputPool, ModelCatalog,
    ModelId, SloReport, SubmitError, SyntheticLoad,
};
use apu::sim::{plan_cache_stats, Apu, ApuConfig};
use apu::util::bench::BenchResult;
use apu::util::rng::Rng;
use apu::util::stats::Summary;
use apu::util::table::Table;

const DIMS: [usize; 3] = [128, 96, 10];
const DIN: usize = 128;
const N_PES: usize = 4;

fn make_engine(shard: usize) -> anyhow::Result<Box<dyn Engine>> {
    let layers = synthetic_packed_network(&DIMS, N_PES, 4, 1000 + shard as u64)?;
    let program = compile_packed_layers("fleet-bench", &layers, 0.15, 4, N_PES)?;
    let apu = Apu::new(ApuConfig { n_pes: N_PES, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    Ok(Box::new(ApuEngine::new(apu, &program)?) as Box<dyn Engine>)
}

/// Burst `n` requests into a fleet and drain; returns aggregate req/s.
fn saturated_throughput(shards: usize, n: usize) -> f64 {
    let fleet = Fleet::start(
        FleetConfig {
            shards,
            policy: DispatchPolicy::JoinShortestQueue,
            batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            queue_cap: usize::MAX, // scaling run: measure service, not admission
            ..FleetConfig::default()
        },
        make_engine,
    )
    .unwrap();
    let mut load = SyntheticLoad::new(1e9, 42);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n).map(|_| fleet.submit(load.next_input(DIN)).unwrap()).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let rps = n as f64 / t0.elapsed().as_secs_f64();
    fleet.shutdown().unwrap();
    rps
}

/// Result-cache experiment: one catalog model with the request-level
/// cache on, inputs drawn from a small Zipf-skewed pool so repeats
/// actually occur. Hit replies are produced inside `submit_to` (before
/// admission), so the hit-path p50 sits far below the engine path.
fn cache_experiment(n: usize) -> Vec<BenchResult> {
    let mut catalog = ModelCatalog::new();
    let cfg = ApuConfig { n_pes: N_PES, pe_sram_bits: 1 << 20, clock_ghz: 1.0 };
    let layers = synthetic_packed_network(&DIMS, N_PES, 4, 3100).unwrap();
    let program = compile_packed_layers("zipf-cache", &layers, 0.15, 4, N_PES).unwrap();
    catalog.add_program("zipf-cache", Arc::new(program), cfg).unwrap();
    println!("== result cache (1 model x 2 shards, Zipf(1.1) pool of 64, 256 entries) ==");
    let fleet = Fleet::start_catalog(
        FleetConfig {
            shards: 0,
            policy: DispatchPolicy::JoinShortestQueue,
            batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            queue_cap: usize::MAX,
            cache_entries: 256,
            ..FleetConfig::default()
        },
        Arc::new(catalog),
        &[2],
    )
    .unwrap();
    let pool = InputPool::zipf(DIN, 64, 1.1, 616);
    let mut rng = Rng::new(99);
    let rxs: Vec<_> =
        (0..n).map(|_| fleet.submit_to(ModelId(0), pool.sample(&mut rng)).unwrap()).collect();
    let (mut hit, mut miss) = (Summary::new(), Summary::new());
    for rx in rxs {
        let r = rx.recv().unwrap();
        r.output.unwrap();
        let ns = r.latency.as_nanos() as f64;
        if r.cached {
            hit.add(ns);
        } else {
            miss.add(ns);
        }
    }
    let metrics = fleet.shutdown().unwrap();
    if let Some(Some(stats)) = metrics.cache.first() {
        println!(
            "cache: {} hits / {} misses ({:.0}% hit rate), {} evictions, {} entries",
            stats.hits,
            stats.misses,
            100.0 * stats.hit_rate(),
            stats.evictions,
            stats.entries
        );
    }
    println!(
        "hit p50 {:.0} ns ({} replies) vs miss p50 {:.0} ns ({} replies)",
        hit.median(),
        hit.count(),
        miss.median(),
        miss.count()
    );
    [("fleet/zipf_cache_hit", hit), ("fleet/zipf_cache_miss", miss)]
        .into_iter()
        .filter(|(_, s)| s.count() > 0)
        .map(|(name, mut s)| BenchResult {
            name: name.to_string(),
            iters: s.count(),
            mean_ns: s.mean(),
            median_ns: s.median(),
            stddev_ns: s.stddev(),
            min_ns: s.min(),
        })
        .collect()
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut json_out: Option<String> = None;
    let mut only: Option<String> = None;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--json" => {
                json_out = argv.get(i + 1).cloned();
                i += 2;
            }
            "--only" => {
                only = argv.get(i + 1).cloned();
                i += 2;
            }
            _ => i += 1, // ignore the harness's own flags (--bench etc.)
        }
    }
    if let Some(what) = &only {
        assert_eq!(what, "cache", "--only supports: cache");
        let results = cache_experiment(512);
        if let Some(path) = &json_out {
            apu::util::bench::write_report(path, &results).unwrap();
            println!("wrote {} bench row(s) to {path}", results.len());
        }
        return;
    }
    let n = 512;
    println!("== fleet scaling (saturating burst, {n} requests, jsq) ==");
    let mut t = Table::new(&["shards", "req/s", "speedup"]);
    let mut base = 0.0;
    for shards in [1usize, 2, 4, 8] {
        let rps = saturated_throughput(shards, n);
        if shards == 1 {
            base = rps;
        }
        t.row(&[shards.to_string(), format!("{rps:.0}"), format!("{:.2}x", rps / base)]);
    }
    println!("{}", t.render());

    // Policy comparison: paced Poisson arrivals at ~1.3x the measured
    // 4-shard capacity, bounded queues so admission control engages.
    let shards = 4;
    let capacity = saturated_throughput(shards, n);
    let rate = 1.3 * capacity;
    println!(
        "== dispatch policies ({shards} shards, rate {rate:.0} req/s ~ 1.3x capacity, queue cap 32) =="
    );
    for policy in DispatchPolicy::ALL {
        let fleet = Fleet::start(
            FleetConfig {
                shards,
                policy,
                batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
                queue_cap: 32,
                ..FleetConfig::default()
            },
            make_engine,
        )
        .unwrap();
        let mut load = SyntheticLoad::new(rate, 7);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            std::thread::sleep(load.next_gap());
            match fleet.submit(load.next_input(DIN)) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::Rejected { .. }) => {} // counted in shard state
                Err(e) => panic!("{e}"),
            }
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let elapsed = t0.elapsed();
        let metrics = fleet.shutdown().unwrap();
        println!("{}", SloReport::from_metrics(&metrics, elapsed).render());
    }

    // Multi-model mix: one catalog fleet serving two differently-sized
    // models on their own shard groups, 80/20 skewed traffic.
    let mut catalog = ModelCatalog::new();
    let cfg = ApuConfig { n_pes: N_PES, pe_sram_bits: 1 << 20, clock_ghz: 1.0 };
    for (name, dims, seed) in
        [("mix-large", &[128usize, 96, 10][..], 2100u64), ("mix-small", &[64, 48, 10][..], 2200)]
    {
        let layers = synthetic_packed_network(dims, N_PES, 4, seed).unwrap();
        let program = compile_packed_layers(name, &layers, 0.15, 4, N_PES).unwrap();
        catalog.add_program(name, Arc::new(program), cfg.clone()).unwrap();
    }
    let dins: Vec<usize> = catalog.iter().map(|(_, e)| e.program.din).collect();
    let weights = [0.8f32, 0.2];
    println!("== multi-model mix (2 models x 2 shards, 80/20 traffic, jsq) ==");
    let fleet = Fleet::start_catalog(
        FleetConfig {
            shards: 0, // sized by shards_per_model
            policy: DispatchPolicy::JoinShortestQueue,
            batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            queue_cap: usize::MAX,
            ..FleetConfig::default()
        },
        Arc::new(catalog),
        &[2, 2],
    )
    .unwrap();
    let cache = plan_cache_stats();
    println!("plan cache: {} builds, {} hits, {} entries", cache.builds, cache.hits, cache.entries);
    let mut load = SyntheticLoad::new(1e9, 99);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let mut pick = load.rng.uniform(0.0, 1.0);
            let mut m = weights.len() - 1;
            for (i, w) in weights.iter().enumerate() {
                if pick < *w {
                    m = i;
                    break;
                }
                pick -= w;
            }
            fleet.submit_to(ModelId(m), load.next_input(dins[m])).unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let elapsed = t0.elapsed();
    let metrics = fleet.shutdown().unwrap();
    println!("{}", SloReport::from_metrics(&metrics, elapsed).render());

    let results = cache_experiment(n);
    if let Some(path) = &json_out {
        apu::util::bench::write_report(path, &results).unwrap();
        println!("wrote {} bench row(s) to {path}", results.len());
    }
}
