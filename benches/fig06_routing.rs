//! Bench: Fig. 6 — routing-network config memory, plus the actual routing
//! scheduler over structured layer pairs (the compile-time cost the mux
//! design trades the hardware for).

use apu::pruning::BlockStructure;
use apu::sched::{build_demand, schedule_routes};
use apu::util::bench::{bench, budget};
use apu::util::rng::Rng;
use apu::{figures, routing::RoutingDesign};

fn main() {
    println!("{}", figures::fig6().render());
    let r = bench("fig6/config_bits_all_designs", budget(), || {
        [64usize, 256, 1024, 4096]
            .iter()
            .map(|&n| {
                RoutingDesign::Mux { n_pes: 10 }.config_bits(n)
                    + RoutingDesign::Clos.config_bits(n)
                    + RoutingDesign::Crossbar.config_bits(n)
            })
            .sum::<f64>()
    });
    println!("{}", r.report());

    // schedule a 4000-activation layer-to-layer shuffle (the Fig. 9 chip's
    // full-layer case: 10 blocks of 400).
    let mut rng = Rng::new(1);
    let prod = BlockStructure::random(4000, 4000, 10, &mut rng).unwrap();
    let cons = BlockStructure::random(4000, 4000, 10, &mut rng).unwrap();
    let r = bench("fig6/schedule_4000_acts_10pe", budget(), || {
        let demand = build_demand(&prod.row_groups, &cons.col_groups).unwrap();
        schedule_routes(&demand).unwrap().n_cycles
    });
    println!("{}", r.report());
    println!("  ({:.1}k activations scheduled/s)", r.per_second(4000.0) / 1e3);
}
