//! Bench: Fig. 9 — chip-spec generation + the §4.3 headline claims.

use apu::figures;
use apu::generator::{DesignInstance, GeneratorConfig};
use apu::util::bench::{bench, budget};

fn main() {
    let (t, _) = figures::fig9().unwrap();
    println!("{}", t.render());
    println!("{}", figures::headline_claims().unwrap().render());
    let r = bench("fig9/generate_instance", budget(), || {
        DesignInstance::generate(GeneratorConfig::default()).unwrap().metrics.tops_per_watt
    });
    println!("{}", r.report());
}
