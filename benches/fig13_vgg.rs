//! Bench: Fig. 13 — VGG-19 per-layer speedup + utilization (APU group-conv
//! mapping vs the EIE-style unstructured baseline).

use apu::compiler::cost::{cost_network, CostModel};
use apu::figures;
use apu::nn::zoo;
use apu::util::bench::{bench, budget};

fn main() {
    println!("{}", figures::fig13().unwrap().render());
    let (best, util, _, _) = figures::fig13_14_summary().unwrap();
    println!("best conv speedup {best:.1}x, mean conv utilization {:.1}%", util * 100.0);
    let net = zoo::vgg19(true);
    let model = CostModel::paper_9pe();
    let r = bench("fig13/cost_vgg19", budget(), || cost_network(&model, &net).unwrap().total_cycles());
    println!("{}", r.report());
}
