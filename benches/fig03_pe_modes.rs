//! Bench: Fig. 3 — temporal vs spatial PE area/energy models.
//! Prints the figure's rows and times the model evaluation.

use apu::figures;
use apu::hwmodel::{pe_energy_per_cycle, PeConfig, PeMode, Tech};
use apu::util::bench::{bench, budget};

fn main() {
    println!("{}", figures::fig3().render());
    let tech = Tech::tsmc16();
    let cfg = PeConfig { block_h: 400, block_w: 400, bits: 4 };
    let r = bench("fig3/pe_energy_both_modes", budget(), || {
        (
            pe_energy_per_cycle(&tech, &cfg, PeMode::Spatial).total(),
            pe_energy_per_cycle(&tech, &cfg, PeMode::Temporal).total(),
        )
    });
    println!("{}", r.report());
}
