"""L1 Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps block counts, block dims, batch, bit widths, and ReLU
on/off; every case asserts exact agreement (interpret-mode Pallas and the
jnp oracle share f32 arithmetic, so tolerance is zero)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import masks
from compile.kernels import block_fc as bfc
from compile.kernels import quant, ref


def _case(rng, nb, bh, bw, batch):
    w = rng.normal(size=(nb, bh, bw)).astype(np.float32)
    a = rng.normal(size=(batch, nb, bw)).astype(np.float32)
    b = rng.normal(size=(nb, bh)).astype(np.float32)
    pre = np.einsum("nhw,bnw->bnh", w, a) + b[None]
    s = (np.maximum(np.abs(pre).max(axis=(0, 2)), 1e-6) / 7).astype(np.float32)
    return map(jnp.asarray, (w, a, b, s))


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 8),
    bh=st.integers(1, 16),
    bw=st.integers(1, 16),
    batch=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
def test_block_fc_matches_ref(nb, bh, bw, batch, seed):
    w, a, b, s = _case(np.random.default_rng(seed), nb, bh, bw, batch)
    got = bfc.block_fc(w, a, b, s, bits=4, relu=True)
    want = ref.block_fc_ref(w, a, b, bits=4, relu=True, out_scale=s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    relu=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_block_fc_bits_relu_modes(bits, relu, seed):
    w, a, b, s = _case(np.random.default_rng(seed), 3, 5, 7, 2)
    got = bfc.block_fc(w, a, b, s, bits=bits, relu=relu)
    want = ref.block_fc_ref(w, a, b, bits=bits, relu=relu, out_scale=s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_fc_no_quant():
    w, a, b, s = _case(np.random.default_rng(0), 4, 8, 8, 2)
    got = bfc.block_fc(w, a, b, s, bits=None, relu=False)
    want = ref.block_fc_ref(w, a, b, bits=None, relu=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_block_fc_shape_validation():
    import pytest

    w = jnp.zeros((2, 3, 4))
    a = jnp.zeros((1, 2, 5))  # bw mismatch
    b = jnp.zeros((2, 3))
    s = jnp.ones((2,))
    with pytest.raises(ValueError):
        bfc.block_fc(w, a, b, s)
    with pytest.raises(ValueError):
        bfc.block_fc(w, jnp.zeros((1, 2, 4)), jnp.zeros((2, 9)), s)
    with pytest.raises(ValueError):
        bfc.block_fc(w, jnp.zeros((1, 2, 4)), b, jnp.ones((3,)))


@settings(max_examples=20, deadline=None)
@given(
    nb=st.integers(1, 6),
    bh=st.integers(1, 8),
    bw=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_packed_equals_masked_dense(nb, bh, bw, seed):
    """Fig. 1 equivalence: the permuted block pipeline computes exactly the
    masked dense layer (no quantization so scales can't hide errors)."""
    rng = np.random.default_rng(seed)
    s = masks.make_structure(nb * bh, nb * bw, nb, seed)
    w_full = rng.normal(size=(s.dout, s.din)).astype(np.float32)
    a_flat = rng.normal(size=(2, s.din)).astype(np.float32)
    bias = rng.normal(size=(s.dout,)).astype(np.float32)

    dense = ref.masked_dense_ref(
        jnp.asarray(w_full), jnp.asarray(s.mask()), jnp.asarray(a_flat), jnp.asarray(bias),
        bits=None, relu=True,
    )

    wb = ref.pack_blocks(jnp.asarray(w_full * s.mask()), jnp.asarray(s.row_groups), jnp.asarray(s.col_groups))
    a_pack = jnp.asarray(a_flat)[:, jnp.asarray(s.col_permutation())].reshape(2, nb, bw)
    b_pack = jnp.asarray(bias)[jnp.asarray(s.row_groups)]
    o = bfc.block_fc(wb, a_pack, b_pack, jnp.ones((nb,)), bits=None, relu=True)
    flat = jnp.zeros((2, s.dout)).at[:, jnp.asarray(s.row_permutation())].set(o.reshape(2, -1))
    np.testing.assert_allclose(np.asarray(flat), np.asarray(dense), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), bits=st.sampled_from([4, 8]))
def test_quantize_activations_kernel(seed, bits):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    s = quant.scale_for(x, bits)
    got = bfc.quantize_activations(x, s, bits=bits)
    want = quant.fake_quant(x, bits, scale=s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(7)
    s = masks.make_structure(12, 20, 4, 3)
    w = rng.normal(size=(12, 20)).astype(np.float32) * s.mask()
    wb = ref.pack_blocks(jnp.asarray(w), jnp.asarray(s.row_groups), jnp.asarray(s.col_groups))
    back = ref.unpack_blocks(wb, jnp.asarray(s.row_groups), jnp.asarray(s.col_groups), 12, 20)
    np.testing.assert_array_equal(np.asarray(back), w)
