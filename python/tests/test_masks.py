"""Property tests for structured-pruning mask generation (paper §2.1)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import masks


@st.composite
def structures(draw):
    nb = draw(st.integers(1, 8))
    bh = draw(st.integers(1, 12))
    bw = draw(st.integers(1, 12))
    seed = draw(st.integers(0, 2**16))
    return masks.make_structure(nb * bh, nb * bw, nb, seed)


@settings(max_examples=50, deadline=None)
@given(s=structures())
def test_groups_partition_indices(s):
    """Every row/col index appears in exactly one group: blocks are
    exclusive (no weight shared between PEs)."""
    assert sorted(s.row_groups.reshape(-1).tolist()) == list(range(s.dout))
    assert sorted(s.col_groups.reshape(-1).tolist()) == list(range(s.din))


@settings(max_examples=50, deadline=None)
@given(s=structures())
def test_mask_density_is_one_over_nb(s):
    m = s.mask()
    assert m.sum() == s.dout * s.din / s.nb
    assert masks.mask_density(s) == pytest.approx(1.0 / s.nb)


@settings(max_examples=50, deadline=None)
@given(s=structures())
def test_permuted_mask_is_block_diagonal(s):
    """After row/col permutation the mask is exactly block-diagonal —
    the paper's Fig. 1 packing property."""
    m = s.mask()[s.row_permutation()][:, s.col_permutation()]
    for g in range(s.nb):
        r0, c0 = g * s.bh, g * s.bw
        block = m[r0 : r0 + s.bh, c0 : c0 + s.bw]
        assert np.all(block == 1.0)
    assert m.sum() == s.nb * s.bh * s.bw  # nothing outside the diagonal


@settings(max_examples=50, deadline=None)
@given(s=structures())
def test_permutations_are_bijective(s):
    for p, n in [(s.col_permutation(), s.din), (s.row_permutation(), s.dout)]:
        assert sorted(p.tolist()) == list(range(n))


def test_rejects_indivisible_dims():
    with pytest.raises(ValueError):
        masks.make_structure(10, 12, 3, 0)


def test_deterministic_by_seed():
    a = masks.make_structure(20, 30, 5, seed=42)
    b = masks.make_structure(20, 30, 5, seed=42)
    assert np.array_equal(a.row_groups, b.row_groups)
    assert np.array_equal(a.col_groups, b.col_groups)
    c = masks.make_structure(20, 30, 5, seed=43)
    assert not (np.array_equal(a.row_groups, c.row_groups) and np.array_equal(a.col_groups, c.col_groups))
