"""Unit + property tests for the INT-k fake quantizer (paper §2.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import quant


def test_qmax_values():
    assert quant.qmax(4) == 7
    assert quant.qmax(8) == 127
    assert quant.qmax(16) == 32767
    assert quant.qmax(2) == 1


def test_qmax_rejects_degenerate():
    with pytest.raises(ValueError):
        quant.qmax(1)


def test_zero_tensor_stays_zero():
    x = jnp.zeros((4, 4))
    assert np.all(np.asarray(quant.fake_quant(x, 4)) == 0.0)


def test_grid_levels_count():
    x = jnp.asarray(np.linspace(-1, 1, 10001, dtype=np.float32))
    y = np.unique(np.asarray(quant.fake_quant(x, 4)))
    assert len(y) == 15  # codes -7..7


@settings(max_examples=40, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 2**16),
    n=st.integers(1, 64),
)
def test_idempotent(bits, seed, n):
    """quant(quant(x)) == quant(x): grid points are fixed points."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32) * 10)
    s = quant.scale_for(x, bits)
    y1 = quant.fake_quant(x, bits, scale=s)
    y2 = quant.fake_quant(y1, bits, scale=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=0, atol=0)


@settings(max_examples=40, deadline=None)
@given(bits=st.sampled_from([3, 4, 8]), seed=st.integers(0, 2**16))
def test_error_bounded_by_half_lsb(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-5, 5, size=(128,)).astype(np.float32))
    s = quant.scale_for(x, bits)
    y = quant.fake_quant(x, bits, scale=s)
    assert float(jnp.abs(y - x).max()) <= float(s) / 2 + 1e-6


@settings(max_examples=30, deadline=None)
@given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
def test_int_roundtrip_matches_fake_quant(bits, seed):
    """Integer codes + dequant == fake-quant: the rust integer datapath
    and the float HLO graph see the same numbers."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    s = quant.scale_for(x, bits)
    codes = quant.quantize_int(x, s, bits)
    assert int(jnp.abs(codes).max()) <= quant.qmax(bits)
    np.testing.assert_allclose(
        np.asarray(quant.dequantize_int(codes, s)),
        np.asarray(quant.fake_quant(x, bits, scale=s)),
        rtol=0, atol=0,
    )


def test_ste_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(quant.fake_quant_ste(x, 4)))(jnp.asarray([0.3, -0.7, 0.11]))
    np.testing.assert_allclose(np.asarray(g), np.ones(3), atol=0)


def test_per_axis_scale_shape():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(5, 8, 3)).astype(np.float32))
    s = quant.scale_for(x, 4, axis=(1, 2))
    assert s.shape == (5, 1, 1)
    y = quant.fake_quant(x, 4, axis=(1, 2))
    assert y.shape == x.shape


def test_monotone_on_grid():
    """Quantization preserves order (weak monotonicity)."""
    x = jnp.asarray(np.sort(np.random.default_rng(3).normal(size=256)).astype(np.float32))
    y = np.asarray(quant.fake_quant(x, 4))
    assert np.all(np.diff(y) >= 0)
