"""Training loop: loss decreases, masks hold, Adam sanity."""

import jax.numpy as jnp
import numpy as np

from compile import datasets, model, train


def test_adam_decreases_quadratic():
    params = {"x": jnp.asarray([5.0])}
    state = train.adam_init(params)
    import jax

    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state = train.adam_update(g, state, params, lr=0.1)
    assert abs(float(params["x"][0])) < 0.1


def test_cross_entropy_perfect_prediction():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]])
    labels = jnp.asarray([0, 1])
    assert float(train.cross_entropy(logits, labels)) < 1e-6


def test_dataset_determinism():
    a = datasets.make_dataset("lenet", n_train=64, n_test=16)
    b = datasets.make_dataset("lenet", n_train=64, n_test=16)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    assert a.dim == 784 and a.classes == 10


def test_short_training_learns_and_preserves_masks():
    r = train.train_model("lenet", True, steps=40, batch=64, log_every=20)
    assert r["losses"][0]["loss"] > r["losses"][-1]["loss"]
    assert r["test_accuracy"] > 0.3  # way above 10% chance even at 40 steps
    # molded pruning: all surviving weights live inside the mask
    for layer in r["params"]["layers"]:
        if layer["mask"] is None:
            continue
        outside = np.asarray(layer["w"]) * (1 - np.asarray(layer["mask"]))
        np.testing.assert_array_equal(outside, np.zeros_like(outside))


def test_dense_baseline_uses_no_mask():
    r = train.train_model("lenet", False, steps=5, batch=32, log_every=5)
    assert r["bits"] is None and r["nb"] == 1
