"""AOT path: bundle format round-trip, HLO text emission, manifest."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_bundle_roundtrip(tmp_path):
    bw = aot.BundleWriter()
    rng = np.random.default_rng(0)
    f = rng.normal(size=(3, 4)).astype(np.float32)
    i = rng.integers(-7, 8, size=(5,)).astype(np.int8)
    u = np.arange(7, dtype=np.uint32)
    bw.add("f", f, "f32")
    bw.add("i", i, "i8")
    bw.add("u", u, "u32")
    jp, bp = str(tmp_path / "m.json"), str(tmp_path / "m.bin")
    bw.write(jp, bp, {"hello": 1})
    doc = json.load(open(jp))
    blob = open(bp, "rb").read()
    assert doc["hello"] == 1
    for name, want in [("f", f), ("i", i), ("u", u)]:
        t = doc["tensors"][name]
        got = np.frombuffer(blob[t["offset"] : t["offset"] + t["bytes"]], dtype=aot._DTYPES[t["dtype"]]).reshape(t["shape"])
        np.testing.assert_array_equal(got, want)


def test_hlo_text_emission():
    def fn(x):
        return (jnp.tanh(x) @ x.T,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((3, 3), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[3,3]" in text


def test_export_model_and_testvec(tmp_path):
    p = model.mlp_init([40, 30, 20, 10], nb=5, seed=1)
    x = np.random.default_rng(0).normal(size=(8, 40)).astype(np.float32)
    packed = model.mlp_pack(p, x[:4])
    meta = aot.export_model(packed, str(tmp_path))
    assert [l["kind"] for l in meta["layers"]] == ["block", "block", "dense"]
    doc = json.load(open(tmp_path / "lenet_model.json"))
    assert doc["bits"] == 4
    # codes within INT4 range
    blob = open(tmp_path / "lenet_model.bin", "rb").read()
    t = doc["tensors"]["l0.w_codes"]
    codes = np.frombuffer(blob[t["offset"] : t["offset"] + t["bytes"]], dtype=np.int8)
    assert np.abs(codes).max() <= 7
    y = np.random.default_rng(1).integers(0, 10, size=8).astype(np.int32)
    aot.export_testvec(packed, x, y, str(tmp_path))
    tv = json.load(open(tmp_path / "testvec.json"))
    assert tv["n"] == 8
