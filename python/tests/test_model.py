"""L2 model tests: train/infer mode equivalence, packing, convnets."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import masks, model


@pytest.fixture(scope="module")
def small_mlp():
    return model.mlp_init([40, 30, 20, 10], nb=5, seed=1)


def test_init_shapes(small_mlp):
    layers = small_mlp["layers"]
    assert layers[0]["w"].shape == (30, 40)
    assert layers[1]["w"].shape == (20, 30)
    assert layers[2]["w"].shape == (10, 20)
    assert layers[2]["structure"] is None  # head stays dense
    assert layers[0]["structure"].nb == 5


def test_forward_train_shapes(small_mlp):
    x = jnp.asarray(np.random.default_rng(0).normal(size=(6, 40)).astype(np.float32))
    y = model.mlp_forward_train(small_mlp, x)
    assert y.shape == (6, 10)
    y32 = model.mlp_forward_train(small_mlp, x, bits=None)
    assert y32.shape == (6, 10)
    assert not np.allclose(np.asarray(y), np.asarray(y32))  # quant does something


def test_masked_weights_do_not_leak(small_mlp):
    """Zeroing all in-mask weights must zero the layer output: nothing
    outside the mask contributes."""
    layers = [dict(l) for l in small_mlp["layers"]]
    l0 = layers[0]
    w_off_mask = np.asarray(l0["w"]) * (1 - np.asarray(l0["mask"]))
    params = {"layers": [{**l0, "w": jnp.asarray(w_off_mask)}] + layers[1:]}
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 40)).astype(np.float32))
    h = model.mlp_forward_train({"layers": params["layers"][:1]}, x, bits=None)
    # single masked layer, no-relu head semantics: output is exactly bias
    np.testing.assert_allclose(np.asarray(h), np.zeros((3, 30)) + np.asarray(l0["b"]), atol=1e-6)


def test_pack_infer_matches_pallas_and_jnp(small_mlp):
    rng = np.random.default_rng(2)
    x = rng.normal(size=(8, 40)).astype(np.float32)
    packed = model.mlp_pack(small_mlp, x[:4])
    y_ref = model.mlp_forward_infer(packed, jnp.asarray(x), use_pallas=False)
    y_pal = model.mlp_forward_infer(packed, jnp.asarray(x), use_pallas=True)
    np.testing.assert_array_equal(np.asarray(y_pal), np.asarray(y_ref))
    assert y_ref.shape == (8, 10)


def test_packed_weights_on_int4_grid(small_mlp):
    packed = model.mlp_pack(small_mlp, np.random.default_rng(0).normal(size=(4, 40)).astype(np.float32))
    for layer in packed["layers"]:
        if layer["kind"] != "block":
            continue
        codes = layer["w_blocks"] / layer["w_scale"][:, None, None]
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
        assert np.abs(codes).max() <= 7 + 1e-4


def test_convnet_forward():
    p = model.convnet_init((8, 8, 1), 10, [4, 8], 32, nb=4, seed=0)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)).astype(np.float32))
    y = model.convnet_forward_train(p, x)
    assert y.shape == (2, 10)
    y32 = model.convnet_forward_train(p, x, bits=None)
    assert y32.shape == (2, 10)


def test_convnet_flat_dim_divisible():
    p = model.convnet_init((28, 28, 1), 10, [16, 32], 128, nb=8, seed=0)
    assert p["flat"] % 8 == 0
