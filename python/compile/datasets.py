"""Synthetic-but-structured datasets for Table 1 (substitution log, DESIGN §2).

We cannot ship MNIST/CIFAR/ImageNet in this environment, and Table 1's
claim is a *delta* — masked+quantized training loses <1% accuracy vs dense
training on the same task — which is observable on any learnable task of
matching geometry. Each dataset is a deterministic Gaussian mixture:
per-class templates (smooth random fields, so pixels correlate like image
data) plus noise, with enough overlap that accuracy is not trivially 100%.

Shapes mirror the paper's models:
  lenet   : 784  (28x28x1),  10 classes  (LeNet-300-100)
  deep    : 784  (28x28x1),  10 classes  (Deep MNIST convnet)
  cifar   : 3072 (32x32x3),  10 classes  (CIFAR10 convnet)
  alexnet : 3072 (32x32x3), 100 classes  (scaled AlexNet-style)
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Dataset", "make_dataset", "SPECS"]

SPECS = {
    "lenet": dict(dim=784, classes=10, image=(28, 28, 1)),
    "deep": dict(dim=784, classes=10, image=(28, 28, 1)),
    "cifar": dict(dim=3072, classes=10, image=(32, 32, 3)),
    "alexnet": dict(dim=3072, classes=100, image=(32, 32, 3)),
}


@dataclass(frozen=True)
class Dataset:
    name: str
    x_train: np.ndarray  # [n, dim] f32 in [-1, 1]
    y_train: np.ndarray  # [n] int32
    x_test: np.ndarray
    y_test: np.ndarray
    image: tuple  # (h, w, c) for conv models

    @property
    def dim(self) -> int:
        return self.x_train.shape[1]

    @property
    def classes(self) -> int:
        return int(self.y_train.max()) + 1


def _smooth_templates(rng: np.random.Generator, classes: int, h: int, w: int, c: int) -> np.ndarray:
    """Per-class smooth random fields: white noise blurred by box filters so
    nearby pixels correlate, like real image statistics."""
    t = rng.normal(size=(classes, h, w, c)).astype(np.float32)
    for _ in range(3):  # separable 3x1 box blur passes
        t = (np.roll(t, 1, axis=1) + t + np.roll(t, -1, axis=1)) / 3.0
        t = (np.roll(t, 1, axis=2) + t + np.roll(t, -1, axis=2)) / 3.0
    t /= np.abs(t).max(axis=(1, 2, 3), keepdims=True)
    return t


def make_dataset(
    name: str,
    n_train: int = 2048,
    n_test: int = 512,
    noise: float = 1.4,
    seed: int = 0,
) -> Dataset:
    """Deterministic Gaussian-mixture classification task."""
    spec = SPECS[name]
    h, w, c = spec["image"]
    classes = spec["classes"]
    rng = np.random.default_rng(seed + hash(name) % (1 << 16))
    templates = _smooth_templates(rng, classes, h, w, c)

    def draw(n: int) -> tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, classes, size=n).astype(np.int32)
        x = templates[y] + noise * rng.normal(size=(n, h, w, c)).astype(np.float32)
        return np.clip(x, -1.0, 1.0).reshape(n, -1).astype(np.float32), y

    x_tr, y_tr = draw(n_train)
    x_te, y_te = draw(n_test)
    return Dataset(name=name, x_train=x_tr, y_train=y_tr, x_test=x_te, y_test=y_te, image=(h, w, c))
