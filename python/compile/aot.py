"""AOT compile path: JAX/Pallas -> HLO text + weights + test vectors.

Runs ONCE at build time (`make artifacts`); python never appears on the
request path. Outputs under artifacts/:

  lenet_b{1,8}.hlo.txt   packed INT4 inference forward (Pallas block_fc,
                         interpret=True) lowered to HLO *text* — the
                         interchange format the rust runtime can parse
                         (serialized protos from jax>=0.5 carry 64-bit ids
                         that xla_extension 0.5.1 rejects).
  block_fc_l1.hlo.txt    the standalone L1 kernel for one LeNet layer —
                         runtime microbenchmarks load this directly.
  lenet_model.{json,bin} the packed model for the rust compiler/simulator:
                         INT4 weight codes, per-block scales, biases,
                         routing permutations, layer graph.
  testvec.{json,bin}     inputs + golden logits from the jnp packed
                         forward; rust integration tests assert the
                         cycle-accurate simulator and the PJRT runtime
                         agree with these.
  manifest.json          index of everything above.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, model, train
from .kernels import block_fc as bfc
from .kernels import quant

BITS = 4
SEED = 0
TRAIN_STEPS = int(os.environ.get("APU_AOT_TRAIN_STEPS", "500"))


# ---------------------------------------------------------------------------
# HLO text emission (see /opt/xla-example/gen_hlo.py and DESIGN.md)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default dump elides any constant with
    # more than 10 elements as `{...}`, which the text parser reads back as
    # zeros — the baked-in weights would silently vanish.
    return comp.as_hlo_text(True)


# ---------------------------------------------------------------------------
# Binary tensor bundle: one .bin blob + JSON manifest of typed views.
# (No npz on the rust side — the bundle reader there is ~80 lines of std.)
# ---------------------------------------------------------------------------

_DTYPES = {"f32": np.float32, "i8": np.int8, "u32": np.uint32, "i32": np.int32}


class BundleWriter:
    def __init__(self):
        self.blob = bytearray()
        self.tensors = {}

    def add(self, name: str, arr: np.ndarray, dtype: str) -> None:
        a = np.ascontiguousarray(arr.astype(_DTYPES[dtype]))
        self.tensors[name] = {
            "dtype": dtype,
            "shape": list(a.shape),
            "offset": len(self.blob),
            "bytes": a.nbytes,
        }
        self.blob.extend(a.tobytes())

    def write(self, json_path: str, bin_path: str, extra: dict | None = None) -> None:
        doc = {"tensors": self.tensors, "bin": os.path.basename(bin_path)}
        if extra:
            doc.update(extra)
        with open(bin_path, "wb") as f:
            f.write(bytes(self.blob))
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=1)


# ---------------------------------------------------------------------------
# Model export
# ---------------------------------------------------------------------------


def export_model(packed: dict, out_dir: str) -> dict:
    """Write the packed model as INT4 codes + scales + permutations."""
    bw_ = BundleWriter()
    layers_meta = []
    q = quant.qmax(BITS)
    for li, layer in enumerate(packed["layers"]):
        if layer["kind"] == "dense":
            w = np.asarray(layer["w"])
            scale = max(np.abs(w).max(), 1e-8) / q
            bw_.add(f"l{li}.w_codes", np.round(w / scale), "i8")
            bw_.add(f"l{li}.b", np.asarray(layer["b"]), "f32")
            layers_meta.append(
                {"kind": "dense", "dout": w.shape[0], "din": w.shape[1],
                 "w_scale": float(scale), "relu": bool(layer["relu"])}
            )
            continue
        s = layer["structure"]
        wb = np.asarray(layer["w_blocks"])  # already on the INT4 grid
        ws = np.asarray(layer["w_scale"])
        codes = np.round(wb / ws[:, None, None])
        assert np.abs(codes).max() <= q
        bw_.add(f"l{li}.w_codes", codes, "i8")
        bw_.add(f"l{li}.w_scale", ws, "f32")
        bw_.add(f"l{li}.b", np.asarray(layer["b_blocks"]), "f32")
        bw_.add(f"l{li}.out_scale", np.asarray(layer["out_scale"]), "f32")
        bw_.add(f"l{li}.col_perm", s.col_permutation(), "u32")
        bw_.add(f"l{li}.row_perm", s.row_permutation(), "u32")
        layers_meta.append(
            {"kind": "block", "nb": s.nb, "bh": s.bh, "bw": s.bw,
             "dout": s.dout, "din": s.din, "relu": bool(layer["relu"])}
        )
    extra = {
        "model": "lenet-300-100",
        "bits": BITS,
        "in_scale": packed["in_scale"],
        "layers": layers_meta,
    }
    bw_.write(os.path.join(out_dir, "lenet_model.json"), os.path.join(out_dir, "lenet_model.bin"), extra)
    return extra


def export_testvec(packed: dict, x: np.ndarray, y: np.ndarray, out_dir: str) -> None:
    logits = np.asarray(model.mlp_forward_infer(packed, jnp.asarray(x), use_pallas=False))
    bw_ = BundleWriter()
    bw_.add("x", x, "f32")
    bw_.add("y", y, "i32")
    bw_.add("logits", logits, "f32")
    bw_.write(
        os.path.join(out_dir, "testvec.json"),
        os.path.join(out_dir, "testvec.bin"),
        {"n": int(x.shape[0]), "accuracy": float((logits.argmax(-1) == y).mean())},
    )


def export_hlo(packed: dict, out_dir: str) -> list[str]:
    files = []
    for batch in (1, 8):
        fn = lambda x: (model.mlp_forward_infer(packed, x, use_pallas=True, interpret=True),)
        spec = jax.ShapeDtypeStruct((batch, 800), jnp.float32)
        text = to_hlo_text(jax.jit(fn).lower(spec))
        path = os.path.join(out_dir, f"lenet_b{batch}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        files.append(os.path.basename(path))

    # Standalone L1 kernel (first masked layer) for runtime microbenches.
    l0 = packed["layers"][0]
    s = l0["structure"]
    w = jnp.asarray(l0["w_blocks"])
    b = jnp.asarray(l0["b_blocks"])
    os_ = jnp.asarray(l0["out_scale"])

    def kfn(a):
        return (bfc.block_fc(w, a, b, os_, bits=BITS, relu=True, interpret=True),)

    spec = jax.ShapeDtypeStruct((1, s.nb, s.bw), jnp.float32)
    text = to_hlo_text(jax.jit(kfn).lower(spec))
    path = os.path.join(out_dir, "block_fc_l1.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    files.append(os.path.basename(path))
    return files


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=TRAIN_STEPS)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    print(f"[aot] training LeNet-300-100 masked+INT4 ({args.steps} steps) ...")
    r = train.train_model("lenet", True, steps=args.steps, seed=SEED)
    print(f"[aot] test accuracy (QAT train graph): {r['test_accuracy']:.4f}")

    print("[aot] packing + calibrating ...")
    packed = model.mlp_pack(r["params"], r["x_test"][:256], bits=BITS)
    logits = np.asarray(model.mlp_forward_infer(packed, jnp.asarray(r["x_test"]), use_pallas=False))
    packed_acc = float((logits.argmax(-1) == r["y_test"]).mean())
    print(f"[aot] test accuracy (packed INT4 graph): {packed_acc:.4f}")

    meta = export_model(packed, args.out)
    export_testvec(packed, r["x_test"][:32], r["y_test"][:32], args.out)
    hlo_files = export_hlo(packed, args.out)

    manifest = {
        "model": meta["model"],
        "bits": BITS,
        "train_steps": args.steps,
        "qat_accuracy": r["test_accuracy"],
        "packed_accuracy": packed_acc,
        "hlo": hlo_files,
        "weights": ["lenet_model.json", "lenet_model.bin"],
        "testvec": ["testvec.json", "testvec.bin"],
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
