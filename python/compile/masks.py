"""Structured-pruning mask generation (paper §2.1, Eq. (1), Fig. 1).

The paper molds pruning during training with binary masks "generated
through random permutation of an identity matrix": rows (output units) and
columns (input units) of each FC weight matrix are randomly partitioned
into ``nb`` equal groups, and weight ``(r, c)`` survives iff ``r`` and
``c`` land in the same group. After permuting rows/cols by group, the mask
is exactly block-diagonal — ``nb`` exclusive dense blocks of shape
``(dout/nb, din/nb)``, each mapping to one PE.

Density is ``1/nb`` (nb=8 -> 12.5%, the paper's most aggressive point;
nb=10 -> 10x compression as in Table 1).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["BlockStructure", "make_structure", "mask_density"]


@dataclass(frozen=True)
class BlockStructure:
    """The per-layer decomposition the mask induces.

    row_groups[g] / col_groups[g] list the original row / column indices
    owned by block ``g`` (sorted within the group — the order is the
    permutation the routing network implements).
    """

    dout: int
    din: int
    nb: int
    row_groups: np.ndarray  # [nb, bh] int32
    col_groups: np.ndarray  # [nb, bw] int32

    @property
    def bh(self) -> int:
        return self.dout // self.nb

    @property
    def bw(self) -> int:
        return self.din // self.nb

    def mask(self) -> np.ndarray:
        """The Eq. (1) binary mask M with M[r,c]=1 iff group(r)==group(c)."""
        m = np.zeros((self.dout, self.din), dtype=np.float32)
        for g in range(self.nb):
            m[np.ix_(self.row_groups[g], self.col_groups[g])] = 1.0
        return m

    def col_permutation(self) -> np.ndarray:
        """Flat input permutation: a_packed = a[col_permutation].

        This is the static route schedule's job on the hardware — the
        routing network delivers activation ``col_groups[g][j]`` to PE
        ``g`` slot ``j`` (paper §3.1.2).
        """
        return self.col_groups.reshape(-1)

    def row_permutation(self) -> np.ndarray:
        """Flat output permutation: o_full[row_permutation] = o_packed."""
        return self.row_groups.reshape(-1)


def make_structure(dout: int, din: int, nb: int, seed: int) -> BlockStructure:
    """Randomly partition rows and columns into ``nb`` balanced groups."""
    if dout % nb or din % nb:
        raise ValueError(f"dims ({dout},{din}) not divisible by nb={nb}")
    rng = np.random.default_rng(seed)
    rp = rng.permutation(dout).reshape(nb, dout // nb)
    cp = rng.permutation(din).reshape(nb, din // nb)
    # Sort within groups: canonical order, and keeps the permutation pure
    # block-gathering (easier to audit in the rust scheduler).
    rp = np.sort(rp, axis=1).astype(np.int32)
    cp = np.sort(cp, axis=1).astype(np.int32)
    return BlockStructure(dout=dout, din=din, nb=nb, row_groups=rp, col_groups=cp)


def mask_density(s: BlockStructure) -> float:
    """Fraction of surviving weights = 1/nb."""
    return 1.0 / s.nb
