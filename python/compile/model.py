"""L2: the paper's network models in JAX, calling the L1 Pallas kernels.

Two execution modes per model:

* **train mode** — masked dense math (Eq. (1)): ``(M ∘ W) a + b`` with
  fake-quant straight-through estimators, so gradients flow while the loss
  sees INT4 numerics. Pruning is "molded" into training by construction —
  the mask is applied every forward, so pruned weights never contribute
  and their gradients are masked at the update (train.py).

* **infer mode** — the packed block-diagonal form the APU executes: the
  Pallas ``block_fc`` kernel over ``[nb, bh, bw]`` blocks with the routing
  permutation applied to activations between layers. This is the graph
  that ``aot.py`` lowers to HLO text for the rust runtime, and whose
  numerics the rust cycle-accurate simulator must match.

The equivalence of the two modes (test_model.py) is the paper's Fig. 1
claim: permuted block-diagonal == masked dense.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import masks
from .kernels import block_fc as bfc
from .kernels import quant, ref

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# Masked MLP (LeNet-300-100 and friends) — pure FC, the APU's home turf.
# ---------------------------------------------------------------------------


def mlp_init(layer_dims: list[int], nb: int, seed: int) -> Params:
    """Initialize a masked MLP: He-init dense weights + block structures.

    The last layer is left dense (classifier heads are small and the paper
    prunes the large FC layers; LeNet-300-100's 100->10 head is not
    divisible into balanced blocks anyway).
    """
    key = jax.random.PRNGKey(seed)
    layers = []
    for li, (din, dout) in enumerate(zip(layer_dims[:-1], layer_dims[1:])):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (dout, din), jnp.float32) * jnp.sqrt(2.0 / din)
        last = li == len(layer_dims) - 2
        structure = None if last else masks.make_structure(dout, din, nb, seed=seed * 131 + li)
        layers.append(
            {
                "w": w,
                "b": jnp.zeros((dout,), jnp.float32),
                "mask": None if structure is None else jnp.asarray(structure.mask()),
                "structure": structure,
            }
        )
    return {"layers": layers}


def mlp_forward_train(params: Params, x: jnp.ndarray, *, bits: int | None = 4) -> jnp.ndarray:
    """Masked dense forward with QAT fake-quant (train mode). Returns logits."""
    h = x if bits is None else quant.fake_quant_ste(x, bits)
    n = len(params["layers"])
    for li, layer in enumerate(params["layers"]):
        w, b = layer["w"], layer["b"]
        if layer["mask"] is not None:
            w = w * layer["mask"]
        if bits is not None:
            w = quant.fake_quant_ste(w, bits)
        h = h @ w.T + b[None, :]
        last = li == n - 1
        if not last:
            h = jnp.maximum(h, 0.0)
            if bits is not None:
                h = quant.fake_quant_ste(h, bits)
    return h


def mlp_pack(params: Params, calib_x: np.ndarray, *, bits: int = 4) -> Params:
    """Freeze a trained masked MLP into the packed inference form.

    Per masked layer: extract the dense blocks, fake-quantize weights on a
    per-block scale, and calibrate the output-activation quantization scale
    from a calibration batch (max |preact| per block over ``calib_x``) —
    the 'quantizer at the end of the adder tree' of Fig. 4a.
    """
    packed_layers = []
    h = quant.fake_quant(jnp.asarray(calib_x), bits)
    in_scale = float(quant.scale_for(jnp.asarray(calib_x), bits))
    n = len(params["layers"])
    for li, layer in enumerate(params["layers"]):
        w = np.asarray(layer["w"])
        b = np.asarray(layer["b"])
        s: masks.BlockStructure | None = layer["structure"]
        last = li == n - 1
        if s is None:
            wq = np.asarray(quant.fake_quant(jnp.asarray(w), bits))
            packed_layers.append({"kind": "dense", "w": wq, "b": b, "relu": not last})
            h = jnp.maximum(h @ wq.T + b[None, :], 0.0) if not last else h @ wq.T + b[None, :]
            continue
        wb = np.asarray(ref.pack_blocks(jnp.asarray(w * np.asarray(layer["mask"])), jnp.asarray(s.row_groups), jnp.asarray(s.col_groups)))
        w_scale = np.maximum(np.abs(wb).max(axis=(1, 2)), 1e-8) / quant.qmax(bits)  # [nb]
        wbq = np.clip(np.round(wb / w_scale[:, None, None]), -quant.qmax(bits), quant.qmax(bits)) * w_scale[:, None, None]
        # Calibrate the per-block output scale on the packed pre-activations.
        a_pack = np.asarray(h)[:, s.col_permutation()].reshape(h.shape[0], s.nb, s.bw)
        pre = np.einsum("nhw,bnw->bnh", wbq, a_pack) + b[s.row_groups][None, :, :]
        post = np.maximum(pre, 0.0)
        out_scale = np.maximum(np.abs(post).max(axis=(0, 2)), 1e-8) / quant.qmax(bits)  # [nb]
        packed_layers.append(
            {
                "kind": "block",
                "w_blocks": wbq.astype(np.float32),
                "w_scale": w_scale.astype(np.float32),
                "b_blocks": b[s.row_groups].astype(np.float32),
                "out_scale": out_scale.astype(np.float32),
                "structure": s,
                "relu": True,
            }
        )
        # Advance calibration activations through this layer (quantized).
        o = ref.block_fc_ref(jnp.asarray(wbq), jnp.asarray(a_pack), jnp.asarray(b[s.row_groups]), bits=bits, relu=True, out_scale=jnp.asarray(out_scale))
        flat = jnp.zeros((h.shape[0], s.dout))
        h = flat.at[:, s.row_permutation()].set(np.asarray(o).reshape(h.shape[0], -1))
    return {"layers": packed_layers, "in_scale": in_scale, "bits": bits}


def mlp_forward_infer(packed: Params, x: jnp.ndarray, *, interpret: bool = True, use_pallas: bool = True) -> jnp.ndarray:
    """Packed inference forward — the graph lowered to HLO for rust.

    Activations are quantized at ingress, then each masked layer gathers
    its block slices (the routing network's static schedule), runs the
    Pallas block kernel, and scatters back (the next layer's gather folds
    into one permutation at AOT time via XLA fusion).
    """
    bits = packed["bits"]
    in_scale = jnp.float32(packed["in_scale"])
    if use_pallas:
        h = bfc.quantize_activations(x, in_scale, bits=bits, interpret=interpret)
    else:
        h = quant.fake_quant(x, bits, scale=in_scale)
    for layer in packed["layers"]:
        if layer["kind"] == "dense":
            h = h @ jnp.asarray(layer["w"]).T + jnp.asarray(layer["b"])[None, :]
            if layer["relu"]:
                h = jnp.maximum(h, 0.0)
            continue
        s: masks.BlockStructure = layer["structure"]
        a = h[:, jnp.asarray(s.col_permutation())].reshape(h.shape[0], s.nb, s.bw)
        if use_pallas:
            o = bfc.block_fc(
                jnp.asarray(layer["w_blocks"]),
                a,
                jnp.asarray(layer["b_blocks"]),
                jnp.asarray(layer["out_scale"]),
                bits=bits,
                relu=layer["relu"],
                interpret=interpret,
            )
        else:
            o = ref.block_fc_ref(
                jnp.asarray(layer["w_blocks"]),
                a,
                jnp.asarray(layer["b_blocks"]),
                bits=bits,
                relu=layer["relu"],
                out_scale=jnp.asarray(layer["out_scale"]),
            )
        flat = jnp.zeros((h.shape[0], s.dout))
        h = flat.at[:, jnp.asarray(s.row_permutation())].set(o.reshape(h.shape[0], -1))
    return h


# ---------------------------------------------------------------------------
# Small convnets (Deep-MNIST / CIFAR / AlexNet-style) — dense quantized convs
# + masked FC head. The paper prunes FC layers; convs map to the APU via
# unrolling / group conv (§4.4.3), which the rust compiler handles at the
# shape level.
# ---------------------------------------------------------------------------


def convnet_init(image: tuple[int, int, int], classes: int, channels: list[int], fc_dim: int, nb: int, seed: int) -> Params:
    """Conv stack (3x3, stride-2 downsampling) + masked FC + dense head."""
    h, w, c = image
    key = jax.random.PRNGKey(seed)
    convs = []
    cin = c
    for cout in channels:
        key, k = jax.random.split(key)
        convs.append(
            {
                "w": jax.random.normal(k, (3, 3, cin, cout), jnp.float32) * jnp.sqrt(2.0 / (9 * cin)),
                "b": jnp.zeros((cout,), jnp.float32),
            }
        )
        cin = cout
        h, w = (h + 1) // 2, (w + 1) // 2
    flat = h * w * cin
    # Pad the flattened dim handling is avoided by construction: image dims
    # are powers-of-two-ish and we choose fc_dim divisible by nb.
    key, k = jax.random.split(key)
    head = mlp_init([flat, fc_dim, classes], nb, seed=seed + 7)
    return {"convs": convs, "head": head, "image": image, "flat": flat}


def convnet_forward_train(params: Params, x: jnp.ndarray, *, bits: int | None = 4) -> jnp.ndarray:
    h_, w_, c_ = params["image"]
    h = x.reshape(x.shape[0], h_, w_, c_)
    if bits is not None:
        h = quant.fake_quant_ste(h, bits)
    for conv in params["convs"]:
        w = conv["w"] if bits is None else quant.fake_quant_ste(conv["w"], bits)
        h = jax.lax.conv_general_dilated(
            h, w, window_strides=(2, 2), padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        h = jnp.maximum(h + conv["b"][None, None, None, :], 0.0)
        if bits is not None:
            h = quant.fake_quant_ste(h, bits)
    h = h.reshape(h.shape[0], -1)
    return mlp_forward_train(params["head"], h, bits=bits)
