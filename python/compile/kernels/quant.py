"""INT-k fake quantization used across the stack (paper §2.2).

The paper runs inference at 4-bit integer precision for weights and
activations, with the quantizer applied at the *end* of the adder tree
(mixed-precision accumulate, quantize once per output activation).

We model this as symmetric uniform fake quantization: values are snapped
to ``2**bits`` levels on a per-tensor (or per-block) scale, but carried in
float so the same graph runs on CPU PJRT. The rust simulator implements
the *true* integer datapath and must agree with this model exactly at the
INT4 grid points — that equivalence is the cross-layer correctness signal
(see rust/tests/integration_golden.rs).

Straight-through estimators make the quantizer trainable (QAT, §2.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "qmax",
    "scale_for",
    "fake_quant",
    "fake_quant_ste",
    "quantize_int",
    "dequantize_int",
]


def qmax(bits: int) -> int:
    """Largest positive code of a symmetric signed ``bits``-bit grid.

    4 bits -> 7 (codes -7..7; -8 unused to keep the grid symmetric, matching
    the sign-magnitude multipliers in the PE datapath).
    """
    if bits < 2:
        raise ValueError(f"quantization needs >=2 bits, got {bits}")
    return (1 << (bits - 1)) - 1


def scale_for(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Symmetric scale so that max|x| maps to the top code.

    ``axis=None`` gives a per-tensor scale; an axis tuple gives per-block /
    per-channel scales (kept on the non-reduced axes).
    """
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    # Avoid a zero scale for all-zero tensors; any non-zero scale quantizes
    # zeros to zeros.
    amax = jnp.where(amax == 0.0, 1.0, amax)
    return amax / qmax(bits)


def quantize_int(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Float -> integer codes (round-to-nearest-even, saturating)."""
    q = qmax(bits)
    return jnp.clip(jnp.round(x / scale), -q, q).astype(jnp.int32)


def dequantize_int(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return codes.astype(jnp.float32) * scale


def fake_quant(x: jnp.ndarray, bits: int, scale: jnp.ndarray | None = None, axis=None) -> jnp.ndarray:
    """Quantize-dequantize: snap ``x`` to its INT-k grid, stay in float."""
    s = scale_for(x, bits, axis=axis) if scale is None else scale
    q = qmax(bits)
    return jnp.clip(jnp.round(x / s), -q, q) * s


def fake_quant_ste(x: jnp.ndarray, bits: int, scale: jnp.ndarray | None = None, axis=None) -> jnp.ndarray:
    """Fake quantization with a straight-through gradient (QAT §2.2).

    Forward value is the quantized grid point; backward is identity, so the
    quantizer is transparent to SGD while the loss sees INT-k numerics.
    """
    y = fake_quant(x, bits, scale=scale, axis=axis)
    return x + jax.lax.stop_gradient(y - x)
