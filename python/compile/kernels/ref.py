"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Two independent formulations of the structured-pruned FC layer:

* :func:`block_fc_ref` — the *packed* formulation the accelerator executes:
  each of ``nb`` dense blocks does an independent mat-vec (paper Fig. 1
  right, Fig. 2), followed by bias, ReLU, and end-of-adder-tree INT-k
  quantization (paper Fig. 4a datapath order).

* :func:`masked_dense_ref` — the *unpacked* formulation the training graph
  uses: a full masked matrix multiply (paper Eq. (1)).

``pack/unpack`` tie the two together; test_kernel.py proves
``pallas == block_fc_ref == permuted masked_dense_ref`` over randomized
shapes, which is exactly the paper's claim that the permuted block-diagonal
network computes the same function as the masked dense one.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import quant

__all__ = ["block_fc_ref", "masked_dense_ref", "pack_blocks", "unpack_blocks"]


def block_fc_ref(
    w: jnp.ndarray,  # [nb, bh, bw] packed dense blocks
    a: jnp.ndarray,  # [batch, nb, bw] permuted activations
    b: jnp.ndarray,  # [nb, bh]
    *,
    bits: int = 4,
    relu: bool = True,
    out_scale: jnp.ndarray | None = None,  # [nb] per-block output scale
) -> jnp.ndarray:  # [batch, nb, bh]
    """Reference block-diagonal FC: per-block mat-vec + bias + ReLU + quant."""
    # einsum over the block axis: each block's activations only ever meet
    # that block's weights — the "exclusive and independent blocks" property.
    o = jnp.einsum("nhw,bnw->bnh", w, a) + b[None, :, :]
    if relu:
        o = jnp.maximum(o, 0.0)
    if bits is not None:
        if out_scale is None:
            o = quant.fake_quant(o, bits)
        else:
            o = quant.fake_quant(o, bits, scale=out_scale[None, :, None])
    return o


def masked_dense_ref(
    w_full: jnp.ndarray,  # [dout, din] dense weights
    mask: jnp.ndarray,  # [dout, din] binary block-structure mask (Eq. 1)
    a: jnp.ndarray,  # [batch, din]
    b: jnp.ndarray,  # [dout]
    *,
    bits: int = 4,
    relu: bool = True,
) -> jnp.ndarray:  # [batch, dout]
    """Reference masked dense FC: (M ∘ W) a + b, then ReLU and quant."""
    o = a @ (w_full * mask).T + b[None, :]
    if relu:
        o = jnp.maximum(o, 0.0)
    if bits is not None:
        o = quant.fake_quant(o, bits)
    return o


def pack_blocks(
    w_full: jnp.ndarray,  # [dout, din]
    row_groups: jnp.ndarray,  # [nb, bh] row indices per block
    col_groups: jnp.ndarray,  # [nb, bw] col indices per block
) -> jnp.ndarray:  # [nb, bh, bw]
    """Extract each block's dense sub-matrix (paper Fig. 1 packing)."""
    return w_full[row_groups[:, :, None], col_groups[:, None, :]]


def unpack_blocks(
    w_blocks: jnp.ndarray,  # [nb, bh, bw]
    row_groups: jnp.ndarray,
    col_groups: jnp.ndarray,
    dout: int,
    din: int,
) -> jnp.ndarray:  # [dout, din] zeros outside the blocks
    """Scatter packed blocks back into the (masked) full matrix."""
    w = jnp.zeros((dout, din), dtype=w_blocks.dtype)
    return w.at[row_groups[:, :, None], col_groups[:, None, :]].set(w_blocks)
