"""L1 Pallas kernel: the structured-pruned fully-connected layer.

This is the paper's compute hot-spot (§3.1): after structured pruning, a
large FC layer is a set of ``nb`` exclusive dense blocks; each block is an
independent mat-vec executed by one PE against weights resident in its
local SRAM.

TPU mapping (DESIGN.md §3 Hardware-Adaptation):

* the grid iterates over blocks — grid step ``i`` *is* PE ``i``'s work;
* ``BlockSpec`` pins block ``i``'s weights ``[bh, bw]`` in VMEM for the
  whole step, reproducing the per-PE weight-SRAM locality (weights never
  move; activations do — the paper's routing-network argument);
* the MXU does the block mat-vec that the ASIC's 400-multiplier array +
  9-stage adder tree does spatially; bias, ReLU and the end-of-tree INT-k
  quantizer fuse into the same kernel, as in the Fig. 4a datapath.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO and the same artifact runs
under the rust runtime. Numerics are validated against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant

__all__ = ["block_fc", "quantize_activations"]


def _block_fc_kernel(a_ref, w_ref, b_ref, s_ref, o_ref, *, bits, relu):
    """One grid step = one PE processing its dense block.

    a_ref: [batch, 1, bw] VMEM   (this block's slice of the activations)
    w_ref: [1, bh, bw]   VMEM   (the PE's resident weight SRAM)
    b_ref: [1, bh]
    s_ref: [1, 1]                (per-block output quantization scale)
    o_ref: [batch, 1, bh]
    """
    a = a_ref[:, 0, :]  # [batch, bw]
    w = w_ref[0]  # [bh, bw]
    # MXU work: [batch, bw] @ [bw, bh]. f32 accumulate == the ASIC's
    # mixed-precision adder tree (quantization only at the end).
    o = jax.lax.dot_general(
        a,
        w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o = o + b_ref[0][None, :]
    if relu:
        o = jnp.maximum(o, 0.0)
    if bits is not None:
        q = quant.qmax(bits)
        s = s_ref[0, 0]
        o = jnp.clip(jnp.round(o / s), -q, q) * s
    o_ref[:, 0, :] = o


@functools.partial(jax.jit, static_argnames=("bits", "relu", "interpret"))
def block_fc(
    w: jnp.ndarray,  # [nb, bh, bw] packed dense blocks
    a: jnp.ndarray,  # [batch, nb, bw] permuted activations
    b: jnp.ndarray,  # [nb, bh] bias per block row
    out_scale: jnp.ndarray,  # [nb] per-block output quant scale
    *,
    bits: int | None = 4,
    relu: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:  # [batch, nb, bh]
    """Structured-pruned FC layer over packed blocks (paper Fig. 2)."""
    nb, bh, bw = w.shape
    batch = a.shape[0]
    if a.shape != (batch, nb, bw):
        raise ValueError(f"activations {a.shape} mismatch blocks {w.shape}")
    if b.shape != (nb, bh):
        raise ValueError(f"bias {b.shape} mismatch blocks {w.shape}")
    if out_scale.shape != (nb,):
        raise ValueError(f"out_scale {out_scale.shape} != ({nb},)")

    kernel = functools.partial(_block_fc_kernel, bits=bits, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((batch, 1, bw), lambda i: (0, i, 0)),
            pl.BlockSpec((1, bh, bw), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, bh), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((batch, 1, bh), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, nb, bh), jnp.float32),
        interpret=interpret,
    )(a, w, b, out_scale.reshape(nb, 1))


def _quantize_kernel(x_ref, s_ref, o_ref, *, bits):
    q = quant.qmax(bits)
    s = s_ref[0]
    o_ref[...] = jnp.clip(jnp.round(x_ref[...] / s), -q, q) * s


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_activations(
    x: jnp.ndarray,  # [batch, d]
    scale: jnp.ndarray,  # [] scalar scale
    *,
    bits: int = 4,
    interpret: bool = True,
) -> jnp.ndarray:
    """Input-side activation quantizer (network ingress, paper §2.2)."""
    return pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits),
        in_specs=[
            pl.BlockSpec(x.shape, lambda: (0,) * x.ndim),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec(x.shape, lambda: (0,) * x.ndim),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x, scale.reshape(1))
