"""Training with mask molding + QAT (paper §2.1–2.2) and the Table 1 runs.

The pruning is "molded throughout the training phase": the block-structure
mask is applied inside every forward (model.py), so masked weights never
contribute, their gradients vanish through the mask, and — belt and
braces — weights are re-masked after every optimizer step. Quantization is
interleaved with the pruning via straight-through fake-quant on weights
and activations, giving the INT4 inference numerics a seat at the training
table (§2.2: "we combine both the quantization and structured pruning
iteratively during the training phase").

Experiments (CLI):
  table1        — each paper model trained twice (ours vs non-compressed);
                  reproduces the accuracy table at ~10x compression.
  density_sweep — accuracy vs block count (density 1/nb), the §2.1 claim
                  that degradation only bites at the most aggressive
                  (12.5%) point.
"""

from __future__ import annotations

import argparse
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model

# ---------------------------------------------------------------------------
# Minimal Adam (no optax in this environment — substrate built from scratch).
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(grads, state, params, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2**t), v)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Loss / metrics
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(logz - logits[jnp.arange(labels.shape[0]), labels])


def accuracy(logits: np.ndarray, labels: np.ndarray) -> float:
    return float((np.argmax(logits, axis=-1) == labels).mean())


# ---------------------------------------------------------------------------
# Train loop
# ---------------------------------------------------------------------------


def _split_trainable(params):
    """Separate jnp leaves (trainable) from structures/masks (static)."""
    if "convs" in params:
        head = params["head"]
        train = {"convs": params["convs"], "head": _split_trainable(head)[0]}
        return train, params
    train = {"layers": [{"w": l["w"], "b": l["b"]} for l in params["layers"]]}
    return train, params


def _merge(train, full):
    if "convs" in full:
        return {**full, "convs": train["convs"], "head": _merge(train["head"], full["head"])}
    layers = [{**fl, "w": tl["w"], "b": tl["b"]} for tl, fl in zip(train["layers"], full["layers"])]
    return {**full, "layers": layers}


def _apply_masks(train, full):
    """Re-mask after the optimizer step: molded pruning never regrows."""
    if "convs" in full:
        return {**train, "head": _apply_masks(train["head"], full["head"])}
    layers = []
    for tl, fl in zip(train["layers"], full["layers"]):
        w = tl["w"] if fl["mask"] is None else tl["w"] * fl["mask"]
        layers.append({"w": w, "b": tl["b"]})
    return {"layers": layers}


def train_model(
    name: str,
    compressed: bool,
    *,
    steps: int = 400,
    batch: int = 128,
    lr: float = 1e-3,
    nb: int | None = None,
    bits: int | None = 4,
    seed: int = 0,
    log_every: int = 50,
    ds: datasets.Dataset | None = None,
) -> dict:
    """Train one Table-1 cell. compressed=False -> dense f32 baseline."""
    ds = ds or datasets.make_dataset(name, seed=seed)
    eff_bits = bits if compressed else None
    if name == "lenet":
        pad = 800 - ds.dim  # pad 784 -> 800 so dims divide nb=10
        x_tr = np.pad(ds.x_train, ((0, 0), (0, pad)))
        x_te = np.pad(ds.x_test, ((0, 0), (0, pad)))
        nb = nb or 10
        params = model.mlp_init([800, 300, 100, ds.classes], nb if compressed else 1, seed)
        fwd = model.mlp_forward_train
    else:
        x_tr, x_te = ds.x_train, ds.x_test
        nb = nb or 8
        channels = {"deep": [16, 32], "cifar": [16, 32], "alexnet": [32, 64, 96]}[name]
        fc_dim = {"deep": 128, "cifar": 256, "alexnet": 256}[name]
        params = model.convnet_init(ds.image, ds.classes, channels, fc_dim, nb if compressed else 1, seed)
        fwd = model.convnet_forward_train
    y_tr, y_te = ds.y_train, ds.y_test

    train_p, full_p = _split_trainable(params)
    opt = adam_init(train_p)

    @jax.jit
    def step(train_p, opt, xb, yb):
        def loss_fn(tp):
            logits = fwd(_merge(tp, full_p), xb, bits=eff_bits)
            return cross_entropy(logits, yb)

        loss, grads = jax.value_and_grad(loss_fn)(train_p)
        train_p, opt = adam_update(grads, opt, train_p, lr=lr)
        train_p = _apply_masks(train_p, full_p)
        return train_p, opt, loss

    rng = np.random.default_rng(seed)
    losses = []
    t0 = time.time()
    for i in range(steps):
        idx = rng.integers(0, x_tr.shape[0], size=batch)
        train_p, opt, loss = step(train_p, opt, jnp.asarray(x_tr[idx]), jnp.asarray(y_tr[idx]))
        if i % log_every == 0 or i == steps - 1:
            losses.append({"step": i, "loss": float(loss)})

    final = _merge(train_p, full_p)
    logits_te = np.asarray(fwd(final, jnp.asarray(x_te), bits=eff_bits))
    logits_tr = np.asarray(fwd(final, jnp.asarray(x_tr[:512]), bits=eff_bits))
    return {
        "model": name,
        "compressed": compressed,
        "nb": nb if compressed else 1,
        "bits": eff_bits,
        "steps": steps,
        "test_accuracy": accuracy(logits_te, y_te),
        "train_accuracy": accuracy(logits_tr, y_tr[:512]),
        "losses": losses,
        "seconds": time.time() - t0,
        "params": final,
        "x_test": x_te,
        "y_test": y_te,
    }


def run_table1(steps: int, out: str | None) -> dict:
    """Paper Table 1: ours (masked + INT4) vs non-compressed, four models."""
    rows = []
    for name in ["lenet", "deep", "cifar", "alexnet"]:
        ds = datasets.make_dataset(name)
        ours = train_model(name, True, steps=steps, ds=ds)
        dense = train_model(name, False, steps=steps, ds=ds)
        rows.append(
            {
                "model": name,
                "ours_acc": ours["test_accuracy"],
                "dense_acc": dense["test_accuracy"],
                "delta": dense["test_accuracy"] - ours["test_accuracy"],
                "compression": ours["nb"],
            }
        )
        print(f"{name:10s} ours={ours['test_accuracy']:.3f} dense={dense['test_accuracy']:.3f} "
              f"delta={rows[-1]['delta']*100:+.2f}pp ({ours['seconds']:.0f}s+{dense['seconds']:.0f}s)")
    result = {"experiment": "table1", "rows": rows}
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {out}")
    return result


def run_density_sweep(steps: int, out: str | None) -> dict:
    """Accuracy vs density (1/nb) on LeNet-300-100 — §2.1's 12.5% claim."""
    rows = []
    ds = datasets.make_dataset("lenet")
    dense = train_model("lenet", False, steps=steps, ds=ds)
    for nb in [2, 4, 5, 8, 10, 20]:
        r = train_model("lenet", True, steps=steps, nb=nb, ds=ds)
        rows.append({"nb": nb, "density": 1.0 / nb, "acc": r["test_accuracy"], "dense_acc": dense["test_accuracy"]})
        print(f"nb={nb:3d} density={100/nb:5.1f}% acc={r['test_accuracy']:.3f}")
    result = {"experiment": "density_sweep", "rows": rows}
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--experiment", choices=["table1", "density_sweep"], default="table1")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.experiment == "table1":
        run_table1(args.steps, args.out)
    else:
        run_density_sweep(args.steps, args.out)


if __name__ == "__main__":
    main()
