#!/usr/bin/env bash
# Tier-1 verify + lint for the rust crate. Run from the repo root.
set -euo pipefail

cargo build --release
# Examples are part of the contract (ROADMAP demos); rot fails the build.
cargo build --release --examples
# Observability smoke: per-layer profile must check exactly against
# SimStats (the command fails if the invariant breaks). --threads 2
# exercises the lane pool: the check also proves threading is bitwise
# invisible to stats/profile.
./target/release/apu profile --net vgg-nano --machine nano --threads 2
cargo test -q
# Perf smoke: the hot-path benches must run, and the machine-readable
# report tracks the perf trajectory from PR 5 onward (short budget —
# this guards against rot, not noise-free numbers). Override the report
# path with BENCH_OUT=... when comparing across branches.
BENCH_OUT=${BENCH_OUT:-BENCH_9.json}
APU_BENCH_MS=60 cargo bench --bench sim_hotpath -- --json "$BENCH_OUT"
test -s "$BENCH_OUT"
cargo fmt --check
cargo clippy --all-targets -- -D warnings
