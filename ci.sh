#!/usr/bin/env bash
# Tier-1 verify + lint for the rust crate. Run from the repo root.
set -euo pipefail

cargo build --release
cargo test -q
cargo clippy --all-targets -- -D warnings
