#!/usr/bin/env bash
# Tier-1 verify + lint for the rust crate. Run from the repo root.
set -euo pipefail

cargo build --release
# Examples are part of the contract (ROADMAP demos); rot fails the build.
cargo build --release --examples
# Observability smoke: per-layer profile must check exactly against
# SimStats (the command fails if the invariant breaks). --threads 2
# exercises the lane pool: the check also proves threading is bitwise
# invisible to stats/profile.
./target/release/apu profile --net vgg-nano --machine nano --threads 2
cargo test -q
# Perf smoke: the hot-path benches must run, and the machine-readable
# report tracks the perf trajectory from PR 5 onward (short budget —
# this guards against rot, not noise-free numbers). Override the report
# path with BENCH_OUT=... when comparing across branches.
BENCH_OUT=${BENCH_OUT:-BENCH_10.json}
APU_BENCH_MS=60 cargo bench --bench sim_hotpath -- --json "$BENCH_OUT"
# Result-cache experiment merges its fleet/zipf_cache_{hit,miss} rows into
# the same report (write_report merges by bench name).
cargo bench --bench fleet_scaling -- --only cache --json "$BENCH_OUT"
test -s "$BENCH_OUT"
# Result-cache smoke: a catalog fleet with the cache on must record hits
# (the driver draws inputs from a Zipf pool, so repeats are guaranteed).
./target/release/apu fleet --models zoo:lenet-5,zoo:vgg-nano --cache 256 \
  --metrics-out fleet_cache_metrics.prom
grep -E 'apu_fleet_cache_hits_total\{[^}]*\} [1-9]' fleet_cache_metrics.prom
rm -f fleet_cache_metrics.prom
cargo fmt --check
cargo clippy --all-targets -- -D warnings
