#!/usr/bin/env bash
# Tier-1 verify + lint for the rust crate. Run from the repo root.
set -euo pipefail

cargo build --release
# Examples are part of the contract (ROADMAP demos); rot fails the build.
cargo build --release --examples
# Observability smoke: per-layer profile must check exactly against
# SimStats (the command fails if the invariant breaks).
./target/release/apu profile --net vgg-nano --machine nano
cargo test -q
cargo fmt --check
cargo clippy --all-targets -- -D warnings
