//! Sharded edge-serving demo: one `Fleet` of APU-simulator engines
//! behind each dispatch policy, showing (1) throughput scaling as shards
//! are added and (2) the SLO cost of a load-blind policy once queues are
//! bounded.
//!
//! Self-contained (synthetic packed network per shard — no artifacts):
//!
//! ```bash
//! cargo run --release --example edge_fleet
//! ```

use std::time::{Duration, Instant};

use apu::compiler::{compile_packed_layers, synthetic_packed_network};
use apu::coordinator::{
    ApuEngine, BatchPolicy, DispatchPolicy, Engine, Fleet, FleetConfig, SloReport, SubmitError,
    SyntheticLoad,
};
use apu::sim::{Apu, ApuConfig};

const DIN: usize = 128;

fn make_engine(shard: usize) -> anyhow::Result<Box<dyn Engine>> {
    // Each shard owns its engine, built inside the shard's worker thread
    // (the factory-closure pattern: PJRT handles are not `Send`).
    let layers = synthetic_packed_network(&[DIN, 96, 10], 4, 4, 77 + shard as u64)?;
    let program = compile_packed_layers("edge-fleet", &layers, 0.15, 4, 4)?;
    let apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    Ok(Box::new(ApuEngine::new(apu, &program)?) as Box<dyn Engine>)
}

fn main() -> anyhow::Result<()> {
    // 1) Scale out: saturating burst, unbounded queues — aggregate
    //    throughput should climb monotonically from 1 to 4 shards.
    let n = 256;
    println!("== scale-out (saturating burst of {n} requests) ==");
    for shards in [1usize, 2, 4, 8] {
        let fleet = Fleet::start(
            FleetConfig {
                shards,
                policy: DispatchPolicy::JoinShortestQueue,
                batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
                queue_cap: usize::MAX,
                ..FleetConfig::default()
            },
            make_engine,
        )?;
        let mut load = SyntheticLoad::new(1e9, 5);
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n).map(|_| fleet.submit(load.next_input(DIN)).unwrap()).collect();
        for rx in rxs {
            rx.recv()?;
        }
        let elapsed = t0.elapsed();
        let m = fleet.shutdown()?;
        println!(
            "  {shards} shard(s): {:>7.0} req/s  (fleet p99 {:.0} us)",
            m.throughput_rps(elapsed),
            m.fleet_latency_us().p99()
        );
    }

    // 2) Policy comparison: paced arrivals, bounded queues (cap 16) —
    //    round-robin rejects while load-aware policies route around
    //    busy shards; the SLO tables make the difference visible.
    let shards = 4;
    let rate = 4000.0;
    println!("\n== dispatch policies ({shards} shards, {rate:.0} req/s, queue cap 16) ==");
    for policy in DispatchPolicy::ALL {
        let fleet = Fleet::start(
            FleetConfig {
                shards,
                policy,
                batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
                queue_cap: 16,
                ..FleetConfig::default()
            },
            make_engine,
        )?;
        let mut load = SyntheticLoad::new(rate, 11);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            std::thread::sleep(load.next_gap());
            match fleet.submit(load.next_input(DIN)) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::Rejected { .. }) => {} // rejection counted per shard
                Err(e) => return Err(e.into()),
            }
        }
        for rx in rxs {
            rx.recv()?;
        }
        let elapsed = t0.elapsed();
        let metrics = fleet.shutdown()?;
        println!("{}", SloReport::from_metrics(&metrics, elapsed).render());
    }
    Ok(())
}
