//! Design-space exploration (paper §4.4, Figs. 10–11): sweep block size
//! and precision; print the energy/area splits and the chip-level impact.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use apu::figures;
use apu::generator::{DesignInstance, GeneratorConfig};

fn main() -> anyhow::Result<()> {
    println!("== block-size sweep (Figs. 10a / 11a) ==");
    println!("{}", figures::fig10_11_block()?.render());
    println!("== precision sweep (Figs. 10b / 11b) ==");
    println!("{}", figures::fig10_11_precision()?.render());

    println!("== chip instances across PE counts ==");
    for n_pes in [4usize, 10, 16, 32] {
        let inst = DesignInstance::generate(GeneratorConfig { n_pes, ..Default::default() })?;
        let m = &inst.metrics;
        println!(
            "  {n_pes:>2} PEs: {:>6.2} mm2, {:>6.0} mW, {:>5.1} TOPS, {:>5.1} TOPS/W",
            m.area_mm2, m.power_mw, m.tops, m.tops_per_watt
        );
    }
    Ok(())
}
