//! End-to-end driver (EXPERIMENTS.md §E2E): proves all layers compose.
//!
//! Python (build time, `make artifacts`): trains LeNet-300-100 with
//! structured-pruning mask molding + INT4 QAT (L2), packs it through the
//! Pallas block kernel graph (L1), and AOT-lowers to HLO text.
//!
//! This binary (the request path, no python):
//!   1. imports the packed model bundle and compiles it to an APU program;
//!   2. runs the full test-vector set on the cycle-accurate simulator;
//!   3. runs the same inputs through the PJRT golden model (the lowered
//!      JAX graph) and checks agreement;
//!   4. reports accuracy, cycles, energy, and the headline TOPS/W.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_lenet
//! ```

use apu::compiler::{compile_packed_layers, import_bundle};
use apu::runtime::{Manifest, Runtime};
use apu::sim::{Apu, ApuConfig};
use apu::util::bundle::Bundle;

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap()
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let model = import_bundle(manifest.model_bundle_path().to_str().unwrap())?;
    println!(
        "imported {}: {} layers, {}-bit, in_scale {:.4}",
        model.name,
        model.layers.len(),
        model.bits,
        model.in_scale
    );

    let program = compile_packed_layers(&model.name, &model.layers, model.in_scale, model.bits, 10)?;
    let mut apu = Apu::new(ApuConfig::default());
    apu.load(&program)?;

    let tv = Bundle::load(manifest.testvec_path())?;
    let x = tv.tensor("x")?.as_f32()?;
    let y = tv.tensor("y")?.as_i32()?;
    let golden_py = tv.tensor("logits")?.as_f32()?;
    let n = tv.shape("x")?[0];
    let din = tv.shape("x")?[1];

    // PJRT golden model (the lowered JAX/Pallas graph).
    let rt = Runtime::cpu()?;
    let exe = rt.load_hlo_text(manifest.hlo_path("lenet_b1")?)?;

    let (mut correct, mut sim_vs_py, mut sim_vs_pjrt) = (0usize, 0f32, 0f32);
    for i in 0..n {
        let xi = &x[i * din..(i + 1) * din];
        let sim = apu.run(xi)?;
        let pjrt = &exe.run_f32(&[(xi, &[1, din as i64])])?[0];
        let py = &golden_py[i * 10..(i + 1) * 10];
        if argmax(&sim) == y[i] as usize {
            correct += 1;
        }
        for k in 0..10 {
            sim_vs_py = sim_vs_py.max((sim[k] - py[k]).abs());
            sim_vs_pjrt = sim_vs_pjrt.max((sim[k] - pjrt[k]).abs());
        }
    }
    let st = apu.stats();
    println!("e2e over {n} test vectors:");
    println!("  INT4 accuracy                {:.3}", correct as f64 / n as f64);
    println!("  max |sim - python golden|    {sim_vs_py:.2e}");
    println!("  max |sim - PJRT golden|      {sim_vs_pjrt:.2e}");
    println!(
        "  cycles/inference             {} ({:.2} us @1GHz)",
        st.total_cycles() / n as u64,
        st.total_cycles() as f64 / n as f64 / 1000.0
    );
    println!("  energy/inference             {:.2} nJ", st.total_pj() / n as f64 / 1e3);
    println!("  datapath efficiency          {:.1} TOPS/W", st.normalized_ops() / st.total_pj());
    anyhow::ensure!(sim_vs_py < 1e-3, "simulator disagrees with python golden");
    anyhow::ensure!(sim_vs_pjrt < 1e-3, "simulator disagrees with PJRT golden");
    println!("ALL LAYERS COMPOSE ✓");
    Ok(())
}
