//! Compile the paper's evaluation networks through the pass-based
//! pipeline (`compiler::pipeline`): VGG-19 and ResNet-50 with group
//! convolutions on the 9×513×513 instance (paper §4.4.3, Figs. 12–14)
//! plus the multi-head-attention mapping (§4.4.4) — analyzed per layer —
//! and then *emit and simulate* two executable programs:
//!
//! * the VGG FC tail at 1/8 width (2560→500→200→10, structured at
//!   nb=10);
//! * `zoo::vgg_nano`, the reduced conv network, end to end on the nano
//!   instance;
//! * `zoo::alexnet_nano`, whose first conv, group conv, and FC blocks
//!   all exceed one nano PE — the §4.4.3-II tiled path with runtime
//!   `FoldAdd` partial-sum folds.
//!
//! ```bash
//! cargo run --release --example compile_vgg
//! ```

use apu::compiler::pipeline::{self, PipelineOptions};
use apu::compiler::CostModel;
use apu::nn::graph::{Layer, LayerKind, Network, Shape};
use apu::nn::zoo;
use apu::sim::Apu;

fn main() -> anyhow::Result<()> {
    let model = CostModel::paper_9pe();
    for net in [zoo::vgg19(true), zoo::resnet50(true), zoo::transformer_mha(8, 512, 64)] {
        let a = pipeline::analyze(&net, &model)?;
        let cost = &a.cost;
        println!(
            "{:<18} {:>12} MACs  {:>12} cycles  {:>7.2} ms @1GHz  util {:>5.1}%",
            cost.network,
            cost.total_macs(),
            cost.total_cycles(),
            cost.seconds(1.0) * 1e3,
            cost.mean_utilization() * 100.0
        );
        // top-3 most expensive layers
        let mut idx: Vec<usize> = (0..cost.layers.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(cost.layers[i].total_cycles()));
        for &i in idx.iter().take(3) {
            let l = &cost.layers[i];
            println!(
                "    {:<14} {:?}: {} cycles (compute {}, route {}, host {}, stream {})",
                l.name, l.case, l.total_cycles(), l.compute_cycles, l.route_cycles, l.host_cycles, l.stream_cycles
            );
        }
    }

    // Executable 1: the VGG FC tail at 1/8 width, structured at nb=10.
    let fc_tail = Network {
        name: "vgg-fc-tail/8".into(),
        input: Shape { h: 1, w: 1, c: 2560 },
        layers: vec![
            Layer { name: "fc6".into(), kind: LayerKind::Fc { dout: 500 }, relu: true },
            Layer { name: "fc7".into(), kind: LayerKind::Fc { dout: 200 }, relu: true },
            Layer { name: "fc8".into(), kind: LayerKind::Fc { dout: 10 }, relu: false },
        ],
    };
    run_executable(&fc_tail, &model)?;

    // Executable 2: the reduced conv network on the nano instance.
    run_executable(&zoo::vgg_nano(), &CostModel::nano_4pe())?;

    // Executable 3: the tiled reference — §4.4.3-II partial-sum folds.
    run_executable(&zoo::alexnet_nano(), &CostModel::nano_4pe())?;
    Ok(())
}

/// Compile through the full pipeline, simulate one inference on the
/// cycle-accurate machine, and check it against the functional reference.
fn run_executable(net: &Network, model: &CostModel) -> anyhow::Result<()> {
    let compiled = pipeline::compile_network(net, model, &PipelineOptions::default())?;
    println!("\n{} emitted on {} PEs:", net.name, model.n_pes);
    print!("{}", compiled.table());
    let mut apu = Apu::new(model.apu_config());
    apu.load(&compiled.program)?;
    let x: Vec<f32> = (0..compiled.program.din).map(|i| (i as f32 * 0.113).sin()).collect();
    let got = apu.run(&x)?;
    let want = compiled.reference_forward(&x)?;
    let maxdiff = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    let st = apu.stats();
    println!(
    "  simulated 1 inference: {} cycles (route {}, compute {}, host {}), {} MACs, |sim - ref| ≤ {maxdiff:.1e}",
        st.total_cycles(),
        st.route_cycles,
        st.compute_cycles,
        st.host_cycles,
        st.macs
    );
    anyhow::ensure!(maxdiff < 1e-4, "simulator diverged from the functional reference");
    Ok(())
}
