//! Compile-and-map demo on the paper's evaluation networks: VGG-19 and
//! ResNet-50 with group convolutions on the 9×513×513 instance
//! (paper §4.4.3, Figs. 12–14), plus the multi-head-attention mapping
//! (§4.4.4).
//!
//! ```bash
//! cargo run --release --example compile_vgg
//! ```

use apu::compiler::cost::{cost_network, CostModel};
use apu::nn::zoo;

fn main() -> anyhow::Result<()> {
    let model = CostModel::paper_9pe();
    for net in [zoo::vgg19(true), zoo::resnet50(true), zoo::transformer_mha(8, 512, 64)] {
        let cost = cost_network(&model, &net)?;
        println!(
            "{:<18} {:>12} MACs  {:>12} cycles  {:>7.2} ms @1GHz  util {:>5.1}%",
            cost.network,
            cost.total_macs(),
            cost.total_cycles(),
            cost.seconds(1.0) * 1e3,
            cost.mean_utilization() * 100.0
        );
        // top-3 most expensive layers
        let mut idx: Vec<usize> = (0..cost.layers.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(cost.layers[i].total_cycles()));
        for &i in idx.iter().take(3) {
            let l = &cost.layers[i];
            println!(
                "    {:<14} {:?}: {} cycles (compute {}, route {}, host {}, stream {})",
                l.name, l.case, l.total_cycles(), l.compute_cycles, l.route_cycles, l.host_cycles, l.stream_cycles
            );
        }
    }
    Ok(())
}
