//! Observability tour: one tracer and one metrics registry watching all
//! three layers of the stack.
//!
//! 1. The compiler records a span per pass while lowering a zoo network.
//! 2. The simulator records a per-layer cycle/energy profile whose totals
//!    are checked (exactly) against `SimStats`.
//! 3. A small fleet serves the compiled network with a private registry;
//!    at shutdown the SLO report is exported as gauges and the registry
//!    is rendered in Prometheus text format.
//!
//! Self-contained (synthetic weights — no artifacts):
//!
//! ```bash
//! cargo run --release --example observability
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use apu::compiler::{pipeline, CostModel, PipelineOptions};
use apu::coordinator::{
    ApuEngine, BatchPolicy, DispatchPolicy, Engine, Fleet, FleetConfig, SloReport, SyntheticLoad,
};
use apu::nn::zoo;
use apu::obs::{Registry, Tracer};
use apu::sim::Apu;
use apu::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let net = zoo::vgg_nano();
    let model = CostModel::nano_4pe();
    let tracer = Tracer::new();

    // 1) Compile with per-pass spans.
    let opts = PipelineOptions { tracer: Some(tracer.clone()), ..Default::default() };
    let compiled = pipeline::compile_network(&net, &model, &opts)?;
    println!(
        "== compiler: {} pass span(s) recorded while lowering {} ==",
        tracer.len(),
        net.name
    );

    // 2) Profiled simulation: every cycle and pJ attributed to a layer,
    //    totals provably equal to the live stats.
    let cfg = model.apu_config();
    let clock_ghz = cfg.clock_ghz;
    let mut sim = Apu::new(cfg);
    sim.load(&compiled.program)?;
    sim.enable_profiling();
    let mut rng = Rng::new(0x0b5e);
    for _ in 0..2 {
        let x: Vec<f32> = (0..compiled.program.din).map(|_| rng.uniform(-1.0, 1.0)).collect();
        sim.run(&x)?;
    }
    let stats = sim.stats().clone();
    let profile = sim.take_profile().expect("profiling enabled");
    profile.check_against(&stats)?;
    let names: Vec<String> = compiled.cost.layers.iter().map(|l| l.name.clone()).collect();
    println!("\n== simulator: per-layer profile (totals == SimStats, checked) ==");
    print!("{}", profile.table(&names));

    // 3) Fleet with a private registry (the CLI uses the global one).
    let registry = Arc::new(Registry::new());
    let din = compiled.program.din;
    let fleet = Fleet::start(
        FleetConfig {
            shards: 2,
            policy: DispatchPolicy::JoinShortestQueue,
            batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
            queue_cap: 64,
            metrics: registry.clone(),
            tracer: Some(tracer.clone()),
            ..FleetConfig::default()
        },
        move |_| Ok(Box::new(ApuEngine::from_compiled(&compiled)?) as Box<dyn Engine>),
    )?;
    let mut load = SyntheticLoad::new(1e6, 3);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..64).map(|_| fleet.submit(load.next_input(din)).unwrap()).collect();
    for rx in rxs {
        rx.recv()?;
    }
    let elapsed = t0.elapsed();
    let fleet_metrics = fleet.shutdown()?;
    SloReport::from_metrics(&fleet_metrics, elapsed).export(&registry);

    println!("\n== fleet: Prometheus exposition (histogram buckets elided) ==");
    for line in registry.render_prometheus().lines() {
        if !line.contains("_bucket{") {
            println!("{line}");
        }
    }
    println!(
        "\ntracer holds {} event(s) across compiler + fleet lanes; \
         `apu profile --trace-out t.json` writes the merged Chrome trace.",
        tracer.len()
    );
    Ok(())
}
