//! Quickstart: generate a design instance, compile a tiny structured-pruned
//! network, simulate an inference, and print the performance counters.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use apu::compiler::emit::{compile_packed_layers, synthetic_packed_network};
use apu::generator::{DesignInstance, GeneratorConfig};
use apu::sim::Apu;

fn main() -> anyhow::Result<()> {
    // 1. Generate a design instance (the paper's Fig. 9 chip).
    let instance = DesignInstance::generate(GeneratorConfig::default())?;
    println!("generated instance:\n{}", instance.netlist());
    println!("spec: {}\n", instance.spec_json());

    // 2. Build a structured-pruned network (10 blocks → 10% density) and
    //    compile it to an APU program with static routing schedules.
    let layers = synthetic_packed_network(&[800, 400, 200, 10], 10, 4, 7)?;
    let program = compile_packed_layers("quickstart-mlp", &layers, 0.15, 4, instance.config.n_pes)?;
    println!(
        "compiled {}: {} instructions, {} segments",
        program.name,
        program.insns.len(),
        program.data.len()
    );

    // 3. Simulate one inference on the cycle-accurate machine.
    let mut apu = Apu::new(instance.apu_config());
    apu.load(&program)?;
    let input: Vec<f32> = (0..800).map(|i| ((i % 15) as f32 - 7.0) * 0.1).collect();
    let logits = apu.run(&input)?;
    println!("logits: {logits:?}");

    let st = apu.stats();
    println!(
        "cycles: {} total (route {}, compute {}, host {})",
        st.total_cycles(),
        st.route_cycles,
        st.compute_cycles,
        st.host_cycles
    );
    println!(
        "energy: {:.2} nJ  ({:.1} TOPS/W on the datapath)",
        st.total_pj() / 1e3,
        st.normalized_ops() / st.total_pj()
    );
    Ok(())
}
