//! Edge-serving demo: the L3 coordinator under a Poisson arrival process,
//! with the cycle-accurate simulator as the inference engine.
//!
//! ```bash
//! make artifacts && cargo run --release --example edge_server
//! ```

use std::time::Duration;

use apu::compiler::{compile_packed_layers, import_bundle};
use apu::coordinator::{ApuEngine, BatchPolicy, Engine, Server, SyntheticLoad};
use apu::runtime::Manifest;
use apu::sim::{Apu, ApuConfig};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let bundle = manifest.model_bundle_path().to_str().unwrap().to_string();

    for (batch, rate) in [(1usize, 100.0f64), (8, 400.0), (8, 2000.0)] {
        let bundle = bundle.clone();
        let server = Server::start(
            move || {
                let model = import_bundle(&bundle)?;
                let program =
                    compile_packed_layers(&model.name, &model.layers, model.in_scale, model.bits, 10)?;
                let apu = Apu::new(ApuConfig::default());
                Ok(Box::new(ApuEngine::new(apu, &program)?) as Box<dyn Engine>)
            },
            BatchPolicy { max_batch: batch, max_wait: Duration::from_millis(2) },
        )?;
        let mut load = SyntheticLoad::new(rate, 9);
        let n = 128;
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::new();
        for _ in 0..n {
            std::thread::sleep(load.next_gap());
            rxs.push(server.submit(load.next_input(800))?);
        }
        for rx in rxs {
            rx.recv()?;
        }
        let elapsed = t0.elapsed();
        let mut m = server.shutdown()?;
        println!(
            "batch={batch} rate={rate:>6.0}req/s  ->  {:.0} req/s served, p50 {:.0}us p99 {:.0}us, mean batch {:.2}",
            m.throughput_rps(elapsed),
            m.latency_us.median(),
            m.latency_us.p99(),
            m.batch_sizes.mean()
        );
    }
    Ok(())
}
