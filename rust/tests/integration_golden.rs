//! Cross-layer golden test: python-trained artifacts → rust compiler →
//! cycle-accurate simulator ↔ PJRT golden model (the lowered JAX/Pallas
//! graph). This is the repo's strongest correctness signal: three
//! independent implementations of the packed INT4 network must agree.
//!
//! Requires `make artifacts`; tests skip (with a note) when absent.

use apu::compiler::{compile_packed_layers, import_bundle};
use apu::runtime::Manifest;
#[cfg(feature = "pjrt")]
use apu::runtime::Runtime;
use apu::sim::{Apu, ApuConfig};
use apu::util::bundle::Bundle;

fn manifest() -> Option<Manifest> {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping golden tests: run `make artifacts` first");
            None
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap()
}

#[test]
fn simulator_matches_python_golden_on_all_testvecs() {
    let Some(m) = manifest() else { return };
    let model = import_bundle(m.model_bundle_path().to_str().unwrap()).unwrap();
    let program = compile_packed_layers(&model.name, &model.layers, model.in_scale, model.bits, 10).unwrap();
    let mut apu = Apu::new(ApuConfig::default());
    apu.load(&program).unwrap();

    let tv = Bundle::load(m.testvec_path()).unwrap();
    let x = tv.tensor("x").unwrap().as_f32().unwrap();
    let golden = tv.tensor("logits").unwrap().as_f32().unwrap();
    let (n, din) = (tv.shape("x").unwrap()[0], tv.shape("x").unwrap()[1]);
    for i in 0..n {
        let out = apu.run(&x[i * din..(i + 1) * din]).unwrap();
        let want = &golden[i * 10..(i + 1) * 10];
        for k in 0..10 {
            assert!(
                (out[k] - want[k]).abs() < 1e-3,
                "sample {i} logit {k}: sim {} vs python {}",
                out[k],
                want[k]
            );
        }
        assert_eq!(argmax(&out), argmax(want), "sample {i} argmax");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_golden_matches_python_golden() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(m.hlo_path("lenet_b1").unwrap()).unwrap();
    let tv = Bundle::load(m.testvec_path()).unwrap();
    let x = tv.tensor("x").unwrap().as_f32().unwrap();
    let golden = tv.tensor("logits").unwrap().as_f32().unwrap();
    let din = tv.shape("x").unwrap()[1];
    for i in 0..8 {
        let out = &exe.run_f32(&[(&x[i * din..(i + 1) * din], &[1, din as i64])]).unwrap()[0];
        for k in 0..10 {
            assert!((out[k] - golden[i * 10 + k]).abs() < 1e-4, "sample {i} logit {k}");
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn batch8_artifact_matches_batch1() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let e1 = rt.load_hlo_text(m.hlo_path("lenet_b1").unwrap()).unwrap();
    let e8 = rt.load_hlo_text(m.hlo_path("lenet_b8").unwrap()).unwrap();
    let tv = Bundle::load(m.testvec_path()).unwrap();
    let x = tv.tensor("x").unwrap().as_f32().unwrap();
    let din = tv.shape("x").unwrap()[1];
    let batch = &x[..8 * din];
    let out8 = &e8.run_f32(&[(batch, &[8, din as i64])]).unwrap()[0];
    for i in 0..8 {
        let out1 = &e1.run_f32(&[(&x[i * din..(i + 1) * din], &[1, din as i64])]).unwrap()[0];
        for k in 0..10 {
            assert!((out1[k] - out8[i * 10 + k]).abs() < 1e-5, "sample {i} logit {k}");
        }
    }
}

#[test]
fn fewer_pes_fold_but_agree() {
    // The same model folded onto 4 PEs must produce identical numerics.
    let Some(m) = manifest() else { return };
    let model = import_bundle(m.model_bundle_path().to_str().unwrap()).unwrap();
    let p10 = compile_packed_layers(&model.name, &model.layers, model.in_scale, model.bits, 10).unwrap();
    let p4 = compile_packed_layers(&model.name, &model.layers, model.in_scale, model.bits, 4).unwrap();
    let mut a10 = Apu::new(ApuConfig::default());
    let mut a4 = Apu::new(ApuConfig { n_pes: 4, ..Default::default() });
    a10.load(&p10).unwrap();
    a4.load(&p4).unwrap();
    let tv = Bundle::load(m.testvec_path()).unwrap();
    let x = tv.tensor("x").unwrap().as_f32().unwrap();
    let din = tv.shape("x").unwrap()[1];
    for i in 0..8 {
        let o10 = a10.run(&x[i * din..(i + 1) * din]).unwrap();
        let o4 = a4.run(&x[i * din..(i + 1) * din]).unwrap();
        assert_eq!(o10, o4, "sample {i}");
    }
    // folding serializes: 4-PE machine burns more compute cycles
    assert!(a4.stats().compute_cycles > a10.stats().compute_cycles);
}
