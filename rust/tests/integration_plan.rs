//! Planner-vs-interpreter equivalence across the whole zoo: every network
//! that compiles must load with a resident `ExecPlan`, and the planned
//! executor (`run` and `run_batch`) must reproduce the sequential
//! interpreter bit-for-bit — outputs, `SimStats`, and `SimProfile` records
//! all identical, on both machine instances, including the streamed
//! alexnet-nano whose per-run weight DMA rides the charge tape. The
//! determinism matrix extends the contract across the lane pool:
//! `run_batch` at threads ∈ {1, 2, 4} (and the lane-major kernel) must
//! match sequential `run` bitwise on every compilable zoo network.

use apu::compiler::pipeline::{compile_network, PipelineOptions};
use apu::compiler::CostModel;
use apu::nn::zoo;
use apu::sim::{Apu, ExecOptions};
use apu::util::rng::Rng;

fn cross_check(model: &CostModel, compiled: &apu::compiler::CompiledNetwork, seed: u64) {
    let mut fast = Apu::new(model.apu_config());
    let mut refr = Apu::new(model.apu_config());
    fast.load(&compiled.program).unwrap();
    refr.load(&compiled.program).unwrap();
    assert!(fast.is_planned(), "{}: planner rejected a compiled zoo program", compiled.program.name);
    fast.enable_profiling();
    refr.enable_profiling();

    let mut rng = Rng::new(seed);
    let inputs: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..compiled.program.din).map(|_| rng.normal()).collect())
        .collect();

    // single-shot planned runs against the interpreter, one input at a time
    for (k, x) in inputs.iter().enumerate() {
        let got = fast.run(x).unwrap();
        let want = refr.run_reference(x).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "{} input {k} output {i}: {g} vs {w}", compiled.program.name);
        }
    }
    assert_eq!(fast.stats(), refr.stats(), "{}: stats diverged", compiled.program.name);
    assert_eq!(
        fast.profile().unwrap().records(),
        refr.profile().unwrap().records(),
        "{}: profile diverged",
        compiled.program.name
    );
    fast.profile().unwrap().check_against(fast.stats()).unwrap();
    assert_eq!(fast.pe_rows_computed(), refr.pe_rows_computed());

    // one batched call over the same inputs equals the same work again:
    // stats counters double exactly, outputs stay bitwise identical
    let refs: Vec<&[f32]> = inputs.iter().map(|x| x.as_slice()).collect();
    let batched = fast.run_batch(&refs).unwrap();
    assert_eq!(batched.len(), inputs.len());
    for (k, (out, x)) in batched.iter().zip(&inputs).enumerate() {
        let want = refr.run_reference(x).unwrap();
        for (i, (&g, &w)) in out.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "{} batch lane {k} output {i}", compiled.program.name);
        }
    }
    assert_eq!(fast.stats(), refr.stats(), "{}: batched stats diverged", compiled.program.name);
    assert_eq!(fast.stats().inferences, 6);
}

#[test]
fn planner_matches_interpreter_on_every_compilable_zoo_network() {
    let machines = [("paper_9pe", CostModel::paper_9pe()), ("nano_4pe", CostModel::nano_4pe())];
    let mut executed: Vec<String> = Vec::new();
    for (mname, model) in &machines {
        for (i, name) in zoo::names().iter().enumerate() {
            let net = zoo::by_name(name).unwrap();
            // the big paper networks are analytic-only on these instances;
            // the planner contract covers whatever actually compiles
            let Ok(compiled) = compile_network(&net, model, &PipelineOptions::default()) else {
                continue;
            };
            cross_check(model, &compiled, 7000 + i as u64);
            executed.push(format!("{mname}/{name}"));
        }
    }
    // the executable zoo entries must actually exercise the planned path
    assert!(executed.contains(&"nano_4pe/vgg-nano".to_string()), "executed: {executed:?}");
    assert!(executed.contains(&"nano_4pe/alexnet-nano".to_string()), "executed: {executed:?}");
    assert!(executed.contains(&"paper_9pe/lenet".to_string()), "executed: {executed:?}");
}

/// `run_batch` across lane-pool widths vs sequential `run`: outputs,
/// `SimStats`, `SimProfile`, and PE row counters must be bitwise equal
/// for every thread count. 5 lanes makes the chunking uneven at 2 and 4
/// workers (3+2 and 2+2+1), so partial chunks are covered too.
fn thread_matrix(model: &CostModel, compiled: &apu::compiler::CompiledNetwork, seed: u64) {
    let name = &compiled.program.name;
    let mut rng = Rng::new(seed);
    let inputs: Vec<Vec<f32>> = (0..5)
        .map(|_| (0..compiled.program.din).map(|_| rng.normal()).collect())
        .collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|x| x.as_slice()).collect();

    let mut seq = Apu::new(model.apu_config());
    seq.load(&compiled.program).unwrap();
    seq.enable_profiling();
    let want: Vec<Vec<f32>> = inputs.iter().map(|x| seq.run(x).unwrap()).collect();

    let variants = [
        ExecOptions { threads: 1, lane_major_kernel: false },
        ExecOptions { threads: 2, lane_major_kernel: false },
        ExecOptions { threads: 4, lane_major_kernel: false },
        // the pre-batch-major kernel must stay an equivalent fallback
        ExecOptions { threads: 3, lane_major_kernel: true },
    ];
    for opts in variants {
        let mut apu = Apu::new(model.apu_config());
        apu.load(&compiled.program).unwrap();
        apu.enable_profiling();
        apu.set_exec_options(opts.clone());
        let got = apu.run_batch(&refs).unwrap();
        assert_eq!(got.len(), want.len());
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.len(), w.len());
            for (i, (&a, &b)) in g.iter().zip(w).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{name} {opts:?} lane {k} output {i}: {a} vs {b}");
            }
        }
        assert_eq!(apu.stats(), seq.stats(), "{name}: stats diverged under {opts:?}");
        assert_eq!(
            apu.profile().unwrap().records(),
            seq.profile().unwrap().records(),
            "{name}: profile diverged under {opts:?}"
        );
        assert_eq!(
            apu.pe_rows_computed(),
            seq.pe_rows_computed(),
            "{name}: PE row counters diverged under {opts:?}"
        );
    }
}

#[test]
fn run_batch_is_bitwise_deterministic_across_thread_counts() {
    let machines = [("paper_9pe", CostModel::paper_9pe()), ("nano_4pe", CostModel::nano_4pe())];
    let mut checked: Vec<String> = Vec::new();
    for (mname, model) in &machines {
        for (i, name) in zoo::names().iter().enumerate() {
            let net = zoo::by_name(name).unwrap();
            let Ok(compiled) = compile_network(&net, model, &PipelineOptions::default()) else {
                continue;
            };
            thread_matrix(model, &compiled, 8100 + i as u64);
            checked.push(format!("{mname}/{name}"));
        }
    }
    assert!(checked.contains(&"nano_4pe/vgg-nano".to_string()), "checked: {checked:?}");
    // streamed path: per-run weight DMA rides the tape under threading too
    assert!(checked.contains(&"nano_4pe/alexnet-nano".to_string()), "checked: {checked:?}");
}

#[test]
fn streamed_alexnet_nano_is_planned_and_batch_matches_sequential() {
    let model = CostModel::nano_4pe();
    let compiled =
        compile_network(&zoo::alexnet_nano(), &model, &PipelineOptions::default()).unwrap();

    let mut batched = Apu::new(model.apu_config());
    let mut seq = Apu::new(model.apu_config());
    batched.load(&compiled.program).unwrap();
    seq.load(&compiled.program).unwrap();
    // the tile union exceeds the nano SRAMs: streamed, yet still planned —
    // the per-run weight DMA charge rides the tape instead of the DMA path
    assert!(batched.is_streamed() && batched.is_planned());
    batched.enable_profiling();
    seq.enable_profiling();

    let mut rng = Rng::new(90210);
    let inputs: Vec<Vec<f32>> =
        (0..4).map(|_| (0..compiled.program.din).map(|_| rng.normal()).collect()).collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|x| x.as_slice()).collect();

    let got = batched.run_batch(&refs).unwrap();
    let want: Vec<Vec<f32>> = inputs.iter().map(|x| seq.run(x).unwrap()).collect();
    for (k, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.len(), w.len());
        for (i, (&a, &b)) in g.iter().zip(w).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "lane {k} output {i}: {a} vs {b}");
        }
    }
    assert_eq!(batched.stats(), seq.stats());
    assert_eq!(batched.profile().unwrap().records(), seq.profile().unwrap().records());
    assert_eq!(batched.stats().inferences, 4);
}
