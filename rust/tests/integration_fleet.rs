//! End-to-end fleet serving: sharding, dispatch, admission control, and
//! the no-request-lost guarantee under burst load.

use std::time::Duration;

use apu::compiler::emit::{compile_packed_layers, synthetic_packed_network};
use apu::coordinator::{
    ApuEngine, BatchPolicy, DispatchPolicy, Engine, Fleet, FleetConfig, SloReport, SubmitError,
    SyntheticLoad,
};
use apu::sim::{Apu, ApuConfig};

fn make_engine(shard: usize) -> anyhow::Result<Box<dyn Engine>> {
    let layers = synthetic_packed_network(&[64, 40, 12], 4, 4, 100 + shard as u64)?;
    let program = compile_packed_layers("fleet-it", &layers, 0.15, 4, 4)?;
    let apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    Ok(Box::new(ApuEngine::new(apu, &program)?))
}

fn config(shards: usize, policy: DispatchPolicy, queue_cap: usize) -> FleetConfig {
    FleetConfig {
        shards,
        policy,
        batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
        queue_cap,
        ..FleetConfig::default()
    }
}

/// Under a hard burst across ≥4 shards with bounded queues, every
/// arrival is accounted for: a reply (success), or an explicit
/// admission rejection. Nothing is lost, nothing hangs.
#[test]
fn burst_load_no_request_lost_or_hanging() {
    for policy in DispatchPolicy::ALL {
        let fleet = Fleet::start(config(4, policy, 16), make_engine).unwrap();
        let mut load = SyntheticLoad::new(1e9, 23);
        let n = 400;
        let mut accepted = Vec::new();
        let mut rejected = 0u64;
        for _ in 0..n {
            match fleet.submit(load.next_input(64)) {
                Ok(rx) => accepted.push(rx),
                Err(SubmitError::Rejected { shard, cap, .. }) => {
                    assert!(shard < 4);
                    assert_eq!(cap, 16);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        let mut replied = 0u64;
        for rx in &accepted {
            let reply = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("accepted request must not hang");
            assert_eq!(reply.output.unwrap().len(), 12, "policy {}", policy.name());
            replied += 1;
        }
        assert_eq!(replied as usize + rejected as usize, n);
        let metrics = fleet.shutdown().unwrap();
        assert_eq!(metrics.completed(), replied, "policy {}", policy.name());
        assert_eq!(metrics.rejected(), rejected, "policy {}", policy.name());
        assert_eq!(metrics.failed(), 0);
    }
}

/// One shard's engine factory fails: the fleet starts degraded, routes
/// around the dead shard, and still neither loses nor hangs requests.
#[test]
fn burst_load_with_one_dead_shard() {
    let fleet = Fleet::start(config(4, DispatchPolicy::JoinShortestQueue, 64), |shard| {
        if shard == 1 {
            anyhow::bail!("shard 1: no device");
        }
        make_engine(shard)
    })
    .unwrap();
    assert_eq!(fleet.alive_shards(), 3);
    let mut load = SyntheticLoad::new(1e9, 31);
    let n = 300;
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for _ in 0..n {
        match fleet.submit(load.next_input(64)) {
            Ok(rx) => accepted.push(rx),
            Err(SubmitError::Rejected { shard, .. }) => {
                assert_ne!(shard, 1, "dead shard must not take traffic");
                rejected += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let n_accepted = accepted.len();
    for rx in accepted {
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("must not hang");
        assert_ne!(reply.shard, 1);
        assert!(reply.output.is_ok());
    }
    assert_eq!(n_accepted + rejected, n);
    let metrics = fleet.shutdown().unwrap();
    assert_eq!(metrics.completed(), n_accepted as u64);
    assert_eq!(metrics.shards[1].completed, 0);
    assert_eq!(metrics.dead.len(), 1);
    assert_eq!(metrics.dead[0].0, 1);
    // The SLO report renders the degraded topology.
    let report = SloReport::from_metrics(&metrics, Duration::from_secs(1)).render();
    assert!(report.contains("dead:"));
}

/// Saturating the fleet with paced load produces a coherent SLO report:
/// fleet percentiles ordered, queue depth bounded by the cap, and
/// per-shard completions summing to the fleet total.
#[test]
fn slo_report_is_coherent_under_load() {
    let cap = 32;
    let fleet = Fleet::start(config(4, DispatchPolicy::LeastOutstanding, cap), make_engine).unwrap();
    let mut load = SyntheticLoad::new(50_000.0, 37);
    let mut accepted = Vec::new();
    for _ in 0..500 {
        std::thread::sleep(load.next_gap());
        if let Ok(rx) = fleet.submit(load.next_input(64)) {
            accepted.push(rx);
        }
    }
    for rx in accepted {
        rx.recv_timeout(Duration::from_secs(30)).expect("must not hang");
    }
    let metrics = fleet.shutdown().unwrap();
    let report = SloReport::from_metrics(&metrics, Duration::from_secs(1));
    assert_eq!(report.fleet.completed, metrics.completed());
    assert!(report.fleet.p50_us <= report.fleet.p95_us);
    assert!(report.fleet.p95_us <= report.fleet.p99_us);
    assert!(report.fleet.max_queue_depth <= cap as f64);
    let per_shard: u64 = report.per_shard.iter().map(|s| s.completed).sum();
    assert_eq!(per_shard, report.fleet.completed);
}

/// The 1-shard fleet behaves exactly like the legacy single-engine
/// server: same outputs for the same input, FIFO within a shard.
#[test]
fn one_shard_fleet_matches_server_semantics() {
    let fleet = Fleet::start(config(1, DispatchPolicy::RoundRobin, 1024), make_engine).unwrap();
    let input: Vec<f32> = (0..64).map(|i| ((i * 7 % 15) as f32 - 7.0) * 0.1).collect();
    let a = fleet.infer(input.clone()).unwrap().into_output().unwrap();
    let b = fleet.infer(input).unwrap().into_output().unwrap();
    assert_eq!(a, b, "same input, same engine, same output");
    let metrics = fleet.shutdown().unwrap();
    assert_eq!(metrics.completed(), 2);
}
