//! End-to-end pipeline validation: a zoo conv network compiles through
//! `compiler::pipeline` into an executable program, runs on the
//! cycle-accurate simulator bit-for-bit against the functional reference,
//! agrees with the analytic cost model on every layer's mapping case and
//! on compute cycles, round-trips through the binary ISA encoding and the
//! on-disk artifact format, and serves behind the sharded fleet.

use apu::compiler::pipeline::{analyze, compile_network, PipelineOptions};
use apu::compiler::{CostModel, MappingCase};
use apu::coordinator::{ApuEngine, BatchPolicy, Engine, Fleet, FleetConfig};
use apu::isa::artifact;
use apu::isa::encode::{decode_stream, encode_stream};
use apu::isa::Program;
use apu::nn::graph::{Layer, LayerKind, Network, Shape};
use apu::nn::zoo;
use apu::sim::Apu;
use apu::util::rng::Rng;

fn nano_compiled() -> apu::compiler::CompiledNetwork {
    compile_network(&zoo::vgg_nano(), &CostModel::nano_4pe(), &PipelineOptions::default()).unwrap()
}

#[test]
fn vgg_nano_executes_and_agrees_with_the_cost_model() {
    let model = CostModel::nano_4pe();
    let compiled = nano_compiled();

    // 1. Mapping agreement: the emitter and the analytic model chose the
    //    same §4.4.3 case for every layer (they share decide_layer).
    assert_eq!(compiled.decisions.len(), compiled.cost.layers.len());
    for (d, lc) in compiled.decisions.iter().zip(&compiled.cost.layers) {
        assert_eq!(d.case, lc.case, "{}: emitter vs cost model", lc.name);
    }
    // The network exercises conv cases I and III, host pooling, a folded
    // batch norm (gone after normalization), and both FC mappings.
    let cases: Vec<MappingCase> = compiled.cost.layers.iter().map(|l| l.case).collect();
    assert!(cases.contains(&MappingCase::ConvSmall));
    assert!(cases.contains(&MappingCase::ConvGroup));
    assert!(cases.contains(&MappingCase::Host));
    assert!(cases.contains(&MappingCase::FcStructured));
    assert!(cases.contains(&MappingCase::FcDense));

    // 2. Functional agreement: the sim reproduces the lowered reference.
    let mut rng = Rng::new(99);
    let x: Vec<f32> = (0..compiled.program.din).map(|_| rng.normal()).collect();
    let want = compiled.reference_forward(&x).unwrap();
    let mut apu = Apu::new(model.apu_config());
    apu.load(&compiled.program).unwrap();
    assert!(!apu.is_streamed(), "vgg-nano must fit on-chip");
    let got = apu.run(&x).unwrap();
    assert_eq!(got.len(), 10);
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-5, "output {i}: {g} vs {w}");
    }

    // 3. Cycle agreement: vgg-nano's geometry divides the PE count
    //    evenly, so the emitted wave structure must match the analytic
    //    compute-cycle count exactly.
    let model_compute: u64 = compiled.cost.layers.iter().map(|l| l.compute_cycles).sum();
    assert_eq!(apu.stats().compute_cycles, model_compute);
    // MAC accounting matches the graph-level count (groups included).
    let net_macs: u64 = analyze(&zoo::vgg_nano(), &model).unwrap().cost.total_macs();
    assert_eq!(apu.stats().macs, net_macs);
}

#[test]
fn conv_cost_model_matches_simulator_cycles() {
    // The conv analogue of integration_sim's FC cross-validation: a
    // single grouped conv whose jobs divide the PE array evenly.
    let net = Network {
        name: "xconv".into(),
        input: Shape { h: 8, w: 8, c: 8 },
        layers: vec![Layer {
            name: "c".into(),
            kind: LayerKind::Conv { cout: 16, kh: 3, kw: 3, stride: 1, groups: 2, padding: 1 },
            relu: true,
        }],
    };
    let model = CostModel::nano_4pe();
    let compiled = compile_network(&net, &model, &PipelineOptions::default()).unwrap();
    assert_eq!(compiled.cost.layers[0].case, MappingCase::ConvGroup);

    let mut apu = Apu::new(model.apu_config());
    apu.load(&compiled.program).unwrap();
    let x: Vec<f32> = (0..compiled.program.din).map(|i| (i as f32 * 0.21).cos()).collect();
    apu.run(&x).unwrap();

    // positions=64 × groups=2 = 128 jobs on 4 PEs → 32 waves × 8 rows.
    assert_eq!(compiled.cost.layers[0].compute_cycles, 256);
    assert_eq!(apu.stats().compute_cycles, compiled.cost.layers[0].compute_cycles);
    assert_eq!(apu.stats().macs, compiled.cost.total_macs());
    // utilization is perfect on this geometry
    assert!((compiled.cost.layers[0].utilization - 1.0).abs() < 1e-9);
}

#[test]
fn conv_program_roundtrips_isa_and_artifact() {
    let compiled = nano_compiled();
    let program = &compiled.program;

    // Binary instruction encoding round-trip on a conv-lowered program.
    let words = encode_stream(&program.insns);
    let decoded = decode_stream(&words).unwrap();
    assert_eq!(program.insns, decoded);

    // On-disk artifact round-trip, then execution equivalence.
    let path = std::env::temp_dir().join(format!("apu-pipeline-{}.apu", std::process::id()));
    program.save(&path).unwrap();
    let loaded = Program::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(program.insns, loaded.insns);
    assert_eq!(program.data, loaded.data);

    let model = &compiled.model;
    let x: Vec<f32> = (0..program.din).map(|i| (i as f32 * 0.17).sin()).collect();
    let mut a1 = Apu::new(model.apu_config());
    let mut a2 = Apu::new(model.apu_config());
    a1.load(program).unwrap();
    a2.load(&loaded).unwrap();
    assert_eq!(a1.run(&x).unwrap(), a2.run(&x).unwrap());
}

#[test]
fn fleet_serves_a_compiled_zoo_network() {
    // The acceptance path: zoo conv network → pipeline → ApuEngine →
    // sharded fleet → responses that match the functional reference.
    let compiled = nano_compiled();
    let din = compiled.program.din;
    let mut rng = Rng::new(4242);
    let inputs: Vec<Vec<f32>> = (0..12).map(|_| (0..din).map(|_| rng.normal()).collect()).collect();
    let want: Vec<Vec<f32>> =
        inputs.iter().map(|x| compiled.reference_forward(x).unwrap()).collect();

    let config = FleetConfig {
        shards: 2,
        batch: BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
        queue_cap: 32,
        ..Default::default()
    };
    let fleet = Fleet::start(config, move |_| {
        Ok(Box::new(ApuEngine::from_compiled(&compiled)?) as Box<dyn Engine>)
    })
    .unwrap();
    assert_eq!(fleet.alive_shards(), 2);

    let receivers: Vec<_> = inputs.iter().map(|x| fleet.submit(x.clone()).unwrap()).collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        let reply = rx.recv().unwrap();
        let out = reply.output.unwrap();
        assert_eq!(out.len(), 10);
        for (j, (&g, &w)) in out.iter().zip(&want[i]).enumerate() {
            assert!((g - w).abs() < 1e-5, "request {i} output {j}: {g} vs {w}");
        }
    }
    let metrics = fleet.shutdown().unwrap();
    assert_eq!(metrics.completed(), 12);
    assert_eq!(metrics.failed(), 0);
}

#[test]
fn case_ii_conv_simulates_exactly_and_matches_the_cost_model() {
    // §4.4.3-II: one ungrouped conv whose 144-column unrolled kernel
    // exceeds the nano instance's 128-wide PE → two column tiles, the
    // second folded into the stream by a runtime FoldAdd.
    let net = Network {
        name: "big-conv".into(),
        input: Shape { h: 8, w: 8, c: 16 },
        layers: vec![Layer {
            name: "c".into(),
            kind: LayerKind::Conv { cout: 32, kh: 3, kw: 3, stride: 1, groups: 1, padding: 1 },
            relu: true,
        }],
    };
    let model = CostModel::nano_4pe();
    let compiled = compile_network(&net, &model, &PipelineOptions::default()).unwrap();
    let d = compiled.decisions[0];
    assert_eq!(d.case, MappingCase::ConvLarge);
    assert!(!d.fits_one_pe(), "must tile: {}x{}", d.th, d.tw);
    assert_eq!((d.th, d.tw), (1, 2));
    // the pure-analysis path reports the identical decision
    assert_eq!(analyze(&net, &model).unwrap().decisions, compiled.decisions);

    let mut apu = Apu::new(model.apu_config());
    apu.load(&compiled.program).unwrap();
    let mut rng = Rng::new(77);
    let x: Vec<f32> = (0..compiled.program.din).map(|_| rng.normal()).collect();
    let got = apu.run(&x).unwrap();
    let want = compiled.reference_forward(&x).unwrap();
    assert_eq!(got.len(), want.len());
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-5, "output {i}: {g} vs {w}");
    }
    // 64 positions × 2 column tiles on 4 PEs → 32 waves × 32 rows.
    assert_eq!(compiled.cost.layers[0].compute_cycles, 1024);
    assert_eq!(apu.stats().compute_cycles, compiled.cost.layers[0].compute_cycles);
    assert_eq!(apu.stats().macs, compiled.cost.total_macs());
    // Host-cycle alignment: the analytic model charges the fold + the
    // deferred ReLU (2048 outputs each, quantizer bypassed on the last
    // layer); the sim additionally charges the ingress quantizer (din)
    // and the padding gather (10×10×16 plane).
    assert_eq!(compiled.cost.layers[0].host_cycles, 2048 + 2048);
    assert_eq!(apu.stats().host_cycles, 1024 + 1600 + compiled.cost.layers[0].host_cycles);
}

#[test]
fn tiled_fc_simulates_exactly_and_matches_the_cost_model() {
    // A structured FC whose 16×256 blocks exceed the 64×128 PE along
    // their columns: each block runs as two tiles, partial sums folded
    // on the host, ReLU applied only after the fold.
    let net = Network {
        name: "big-fc".into(),
        input: Shape { h: 1, w: 1, c: 1024 },
        layers: vec![Layer { name: "fc".into(), kind: LayerKind::Fc { dout: 64 }, relu: true }],
    };
    let model = CostModel::nano_4pe();
    let compiled = compile_network(&net, &model, &PipelineOptions::default()).unwrap();
    let d = compiled.decisions[0];
    assert_eq!(d.case, MappingCase::FcStructured);
    assert_eq!((d.th, d.tw), (1, 2));
    assert_eq!(analyze(&net, &model).unwrap().decisions, compiled.decisions);

    let mut apu = Apu::new(model.apu_config());
    apu.load(&compiled.program).unwrap();
    let x: Vec<f32> = (0..1024).map(|i| (i as f32 * 0.13).sin()).collect();
    let got = apu.run(&x).unwrap();
    let want = compiled.reference_forward(&x).unwrap();
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-5, "output {i}: {g} vs {w}");
    }
    // 4 blocks × 2 column tiles on 4 PEs → 2 waves × 16 rows.
    assert_eq!(compiled.cost.layers[0].compute_cycles, 32);
    assert_eq!(apu.stats().compute_cycles, compiled.cost.layers[0].compute_cycles);
    assert_eq!(apu.stats().macs, compiled.cost.total_macs());
    // Host-cycle alignment: fold (64) + deferred ReLU (64); the sim
    // additionally charges the ingress quantizer (din = 1024).
    assert_eq!(compiled.cost.layers[0].host_cycles, 64 + 64);
    assert_eq!(apu.stats().host_cycles, 1024 + compiled.cost.layers[0].host_cycles);
}

#[test]
fn alexnet_nano_executes_tiled_end_to_end() {
    // The zoo's §4.4.3-II reference network: ConvLarge, a tiled group
    // conv, a column-tiled structured FC, and a dense head, all through
    // one program.
    let model = CostModel::nano_4pe();
    let compiled = compile_network(&zoo::alexnet_nano(), &model, &PipelineOptions::default()).unwrap();

    // analyze and compile report identical mapping decisions per layer
    let a = analyze(&zoo::alexnet_nano(), &model).unwrap();
    assert_eq!(a.decisions, compiled.decisions);
    assert_eq!(compiled.decisions[0].case, MappingCase::ConvLarge);
    assert_eq!(compiled.decisions[2].case, MappingCase::ConvGroup);
    assert!(!compiled.decisions[2].fits_one_pe(), "conv2 must tile");
    assert_eq!(compiled.decisions[4].case, MappingCase::FcStructured);
    assert_eq!(compiled.decisions[4].tw, 2);
    assert_eq!(compiled.decisions[5].case, MappingCase::FcDense);

    let mut apu = Apu::new(model.apu_config());
    apu.load(&compiled.program).unwrap();
    // the union of tile weights exceeds the nano PE SRAMs: the program
    // streams weights per run (the AlexNet-flavored Fig. 15 dip)
    assert!(apu.is_streamed());
    let mut rng = Rng::new(123);
    let x: Vec<f32> = (0..compiled.program.din).map(|_| rng.normal()).collect();
    let got = apu.run(&x).unwrap();
    let want = compiled.reference_forward(&x).unwrap();
    assert_eq!(got.len(), 10);
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-5, "output {i}: {g} vs {w}");
    }
    // every tiled geometry divides the machine evenly, so emitted waves
    // match the analytic packing exactly
    let model_compute: u64 = compiled.cost.layers.iter().map(|l| l.compute_cycles).sum();
    assert_eq!(apu.stats().compute_cycles, model_compute);
    assert_eq!(apu.stats().macs, compiled.cost.total_macs());
}

#[test]
fn fleet_serves_the_tiled_zoo_network() {
    // Acceptance path for case II: alexnet-nano behind the sharded
    // fleet (`apu fleet --model zoo:alexnet-nano`), replies matching
    // the functional reference.
    let model = CostModel::nano_4pe();
    let compiled = compile_network(&zoo::alexnet_nano(), &model, &PipelineOptions::default()).unwrap();
    let din = compiled.program.din;
    let mut rng = Rng::new(31337);
    let inputs: Vec<Vec<f32>> = (0..6).map(|_| (0..din).map(|_| rng.normal()).collect()).collect();
    let want: Vec<Vec<f32>> =
        inputs.iter().map(|x| compiled.reference_forward(x).unwrap()).collect();

    let config = FleetConfig {
        shards: 2,
        batch: BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
        queue_cap: 32,
        ..Default::default()
    };
    let fleet = Fleet::start(config, move |_| {
        Ok(Box::new(ApuEngine::from_compiled(&compiled)?) as Box<dyn Engine>)
    })
    .unwrap();
    let receivers: Vec<_> = inputs.iter().map(|x| fleet.submit(x.clone()).unwrap()).collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        let out = rx.recv().unwrap().output.unwrap();
        for (j, (&g, &w)) in out.iter().zip(&want[i]).enumerate() {
            assert!((g - w).abs() < 1e-5, "request {i} output {j}: {g} vs {w}");
        }
    }
    let metrics = fleet.shutdown().unwrap();
    assert_eq!(metrics.completed(), 6);
    assert_eq!(metrics.failed(), 0);
}

#[test]
fn tiled_program_roundtrips_v2_artifact_and_rejects_v1() {
    let compiled = compile_network(&zoo::alexnet_nano(), &CostModel::nano_4pe(), &PipelineOptions::default())
        .unwrap();
    let bytes = artifact::to_bytes(&compiled.program);
    assert_eq!(&bytes[..4], b"APU2");
    let loaded = artifact::from_bytes(&bytes).unwrap();
    assert_eq!(compiled.program.insns, loaded.insns);
    assert_eq!(compiled.program.data, loaded.data);

    // execution equivalence of the round-tripped tiled program
    let model = &compiled.model;
    let x: Vec<f32> = (0..compiled.program.din).map(|i| (i as f32 * 0.19).cos()).collect();
    let mut a1 = Apu::new(model.apu_config());
    let mut a2 = Apu::new(model.apu_config());
    a1.load(&compiled.program).unwrap();
    a2.load(&loaded).unwrap();
    assert_eq!(a1.run(&x).unwrap(), a2.run(&x).unwrap());

    // an old-version blob is refused with a clear error
    let mut old = bytes.clone();
    old[..4].copy_from_slice(b"APU1");
    let msg = format!("{:#}", artifact::from_bytes(&old).unwrap_err());
    assert!(msg.contains("unsupported artifact version"), "{msg}");
}

#[test]
fn maxpool_host_charge_matches_the_cost_model() {
    let net = Network {
        name: "pool-only".into(),
        input: Shape { h: 4, w: 4, c: 2 },
        layers: vec![Layer {
            name: "p".into(),
            kind: LayerKind::MaxPool { window: 2, stride: 2 },
            relu: false,
        }],
    };
    let model = CostModel::nano_4pe();
    let compiled = compile_network(&net, &model, &PipelineOptions::default()).unwrap();
    // per output: win² loads + win²−1 max-combines
    assert_eq!(compiled.cost.layers[0].host_cycles, 8 * 7);
    let mut apu = Apu::new(model.apu_config());
    apu.load(&compiled.program).unwrap();
    let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.3).sin()).collect();
    apu.run(&x).unwrap();
    // the ingress quantizer charges din; the pool charges exactly the
    // analytic figure
    assert_eq!(apu.stats().host_cycles, 32 + compiled.cost.layers[0].host_cycles);
}

#[test]
fn analysis_covers_the_full_zoo() {
    // Every zoo network flows through the passes + shared mapping, even
    // the ones whose emission is analytic-only.
    let model = CostModel::paper_9pe();
    for name in ["lenet", "alexnet", "alexnet-nano", "vgg19", "resnet50", "vgg-nano", "mha"] {
        let net = zoo::by_name(name).unwrap();
        let a = analyze(&net, &model).unwrap();
        assert!(a.cost.total_cycles() > 0, "{name} costs nothing?");
        assert_eq!(a.decisions.len(), a.cost.layers.len());
        for (d, lc) in a.decisions.iter().zip(&a.cost.layers) {
            assert_eq!(d.case, lc.case, "{name}/{}", lc.name);
        }
    }
}

#[test]
fn lenet_compiles_through_the_pipeline_on_the_paper_instance() {
    // The FC-only zoo entry stays executable through the generic path.
    let model = CostModel::paper_9pe();
    let compiled =
        compile_network(&zoo::lenet_300_100(), &model, &PipelineOptions::default()).unwrap();
    assert!(compiled.cost.layers.iter().all(|l| l.case == MappingCase::FcStructured));
    let mut apu = Apu::new(model.apu_config());
    apu.load(&compiled.program).unwrap();
    let x: Vec<f32> = (0..800).map(|i| (i as f32 * 0.05).sin()).collect();
    let got = apu.run(&x).unwrap();
    let want = compiled.reference_forward(&x).unwrap();
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-4, "output {i}: {g} vs {w}");
    }
}
