//! Simulator ↔ cost-model ↔ functional-reference agreement, plus
//! randomized property sweeps over the whole compile-simulate pipeline
//! (the proptest role — deterministic seeds, shrink-by-rerun).

use apu::compiler::cost::{cost_network, CostModel, MappingCase};
use apu::compiler::emit::{compile_packed_layers, synthetic_packed_network};
use apu::nn::graph::{Layer, LayerKind, Network, Shape};
use apu::pruning::Quantizer;
use apu::sim::{Apu, ApuConfig};
use apu::util::rng::Rng;

/// Functional reference: quantize then fold through PackedLayer::forward.
fn reference(layers: &[apu::pruning::PackedLayer], input: &[f32], in_scale: f32) -> Vec<f32> {
    let q = Quantizer::new(4, in_scale);
    let mut h: Vec<f32> = input.iter().map(|&x| q.fake(x)).collect();
    for l in layers {
        h = l.forward(&h).unwrap();
    }
    h
}

#[test]
fn random_networks_simulate_exactly() {
    // 20 random network shapes × machine geometries: sim == reference.
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let nb = 2 + rng.usize_below(5);
        let depth = 1 + rng.usize_below(3);
        let mut dims = vec![nb * (2 + rng.usize_below(8))];
        for _ in 0..depth {
            dims.push(nb * (1 + rng.usize_below(8)));
        }
        let n_pes = 1 + rng.usize_below(nb + 2);
        let layers = synthetic_packed_network(&dims, nb, 4, seed * 7 + 1).unwrap();
        let program = compile_packed_layers("prop", &layers, 0.11, 4, n_pes).unwrap();
        let mut apu = Apu::new(ApuConfig { n_pes, pe_sram_bits: 1 << 22, clock_ghz: 1.0 });
        apu.load(&program).unwrap();
        let input: Vec<f32> = (0..dims[0]).map(|_| rng.normal()).collect();
        let got = apu.run(&input).unwrap();
        let want = reference(&layers, &input, 0.11);
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-4,
                "seed {seed} (dims {dims:?}, nb {nb}, pes {n_pes}) output {i}: {g} vs {w}"
            );
        }
    }
}

#[test]
fn cost_model_matches_simulator_cycle_counts() {
    // The analytic model must reproduce the functional simulator's
    // compute-cycle accounting for unfolded structured FC stacks.
    for seed in [3u64, 9, 21] {
        let nb = 5;
        let dims = [40usize, 30, 20];
        let layers = synthetic_packed_network(&dims, nb, 4, seed).unwrap();
        let program = compile_packed_layers("cc", &layers, 0.1, 4, nb).unwrap();
        let mut apu = Apu::new(ApuConfig { n_pes: nb, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
        apu.load(&program).unwrap();
        let input: Vec<f32> = (0..40).map(|i| (i as f32 * 0.1).sin()).collect();
        apu.run(&input).unwrap();

        let net = Network {
            name: "cc".into(),
            input: Shape { h: 1, w: 1, c: 40 },
            layers: vec![
                Layer { name: "fc1".into(), kind: LayerKind::Fc { dout: 30 }, relu: true },
                Layer { name: "fc2".into(), kind: LayerKind::Fc { dout: 20 }, relu: true },
            ],
        };
        let model = CostModel {
            n_pes: nb,
            pe_h: 1 << 10,
            pe_w: 1 << 10,
            bits: 4,
            clock_ghz: 1.0,
            fc_blocks: Some(nb),
            group_conv: true,
            dma_bits_per_cycle: 64,
        };
        let cost = cost_network(&model, &net).unwrap();
        assert_eq!(cost.layers[0].case, MappingCase::FcStructured);
        let model_compute: u64 = cost.layers.iter().map(|l| l.compute_cycles).sum();
        assert_eq!(
            apu.stats().compute_cycles,
            model_compute,
            "seed {seed}: sim {} vs model {model_compute}",
            apu.stats().compute_cycles
        );
    }
}

#[test]
fn energy_conservation_across_batches() {
    // Energy and cycles scale exactly linearly with inference count.
    let layers = synthetic_packed_network(&[24, 18, 12], 3, 4, 5).unwrap();
    let program = compile_packed_layers("e", &layers, 0.1, 4, 3).unwrap();
    let mut apu = Apu::new(ApuConfig { n_pes: 3, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    apu.load(&program).unwrap();
    let input = vec![0.25f32; 24];
    apu.run(&input).unwrap();
    let (c1, e1) = (apu.stats().total_cycles(), apu.stats().total_pj());
    for _ in 0..4 {
        apu.run(&input).unwrap();
    }
    assert_eq!(apu.stats().total_cycles(), 5 * c1);
    assert!((apu.stats().total_pj() - 5.0 * e1).abs() < 1e-6);
}

#[test]
fn program_encode_decode_executes_identically() {
    // ISA round-trip: decode(encode(insns)) drives the sim to the same result.
    use apu::isa::encode::{decode_stream, encode_stream};
    let layers = synthetic_packed_network(&[20, 15, 10], 5, 4, 11).unwrap();
    let program = compile_packed_layers("rt", &layers, 0.1, 4, 5).unwrap();
    let words = encode_stream(&program.insns);
    let decoded = decode_stream(&words).unwrap();
    let mut program2 = program.clone();
    program2.insns = decoded;

    let input: Vec<f32> = (0..20).map(|i| (i as f32 * 0.3).cos()).collect();
    let mut a1 = Apu::new(ApuConfig { n_pes: 5, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    let mut a2 = Apu::new(ApuConfig { n_pes: 5, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    a1.load(&program).unwrap();
    a2.load(&program2).unwrap();
    assert_eq!(a1.run(&input).unwrap(), a2.run(&input).unwrap());
}

#[test]
fn corrupted_program_is_rejected_not_miscomputed() {
    // Failure injection: breaking a segment reference must error, never
    // silently produce numbers.
    let layers = synthetic_packed_network(&[12, 8], 2, 4, 13).unwrap();
    let mut program = compile_packed_layers("bad", &layers, 0.1, 4, 2).unwrap();
    // point a LoadWeights at a f32 segment
    for insn in &mut program.insns {
        if let apu::isa::Insn::LoadWeights { seg, .. } = insn {
            *seg = 0; // segment 0 is the quantize params (f32)
            break;
        }
    }
    let mut apu = Apu::new(ApuConfig { n_pes: 2, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    assert!(apu.load(&program).is_err());
}

#[test]
fn weight_code_overflow_rejected_at_run() {
    use apu::isa::{DataSegment, Insn};
    let layers = synthetic_packed_network(&[12, 8], 2, 4, 14).unwrap();
    let mut program = compile_packed_layers("ovf", &layers, 0.1, 4, 2).unwrap();
    // corrupt a weight code beyond INT4
    for (i, seg) in program.data.iter_mut().enumerate() {
        if let DataSegment::I8(codes) = seg {
            codes[0] = 100;
            let _ = i;
            break;
        }
    }
    let mut apu = Apu::new(ApuConfig { n_pes: 2, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    apu.load(&program).unwrap();
    let err = apu.run(&vec![0.1; 12]);
    assert!(err.is_err(), "overflowing code must be caught");
    // and the error is the PE's range check, not a panic
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("INT"), "unexpected error: {msg}");
    let _ = Insn::Halt;
}
