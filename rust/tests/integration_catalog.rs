//! Model-keyed serving end to end: content fingerprints are stable and
//! content-sensitive, N simulators loading the same model pay exactly one
//! plan build through the process-wide cache (with bitwise-identical
//! outputs and stats whether the plan was shared or built privately), and
//! a catalog-backed fleet routes mixed-model traffic to per-model shard
//! groups with per-model SLO accounting.

use std::sync::Arc;
use std::time::Duration;

use apu::compiler::{compile_packed_layers, synthetic_packed_network};
use apu::coordinator::{
    BatchPolicy, DispatchPolicy, Fleet, FleetConfig, ModelCatalog, SloReport, SyntheticLoad,
};
use apu::isa::artifact::to_bytes;
use apu::isa::{fingerprint_bytes, Program};
use apu::obs::metrics::Registry;
use apu::sim::{plan_cache_builds, shared_plan, Apu, ApuConfig};
use apu::util::rng::Rng;

/// A small synthetic packed-FC program. Seeds must be unique per test in
/// this binary: the plan cache is process-wide, so per-key build-count
/// assertions rely on each test exercising its own fingerprints.
fn test_program(dims: &[usize], seed: u64, name: &str) -> Program {
    let layers = synthetic_packed_network(dims, 4, 4, seed).unwrap();
    compile_packed_layers(name, &layers, 0.2, 4, 4).unwrap()
}

fn test_cfg() -> ApuConfig {
    ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 }
}

#[test]
fn fingerprint_is_stable_and_content_sensitive() {
    // identical construction → identical canonical bytes → identical hash
    let a = test_program(&[16, 20, 12], 9001, "fp-stable");
    let b = test_program(&[16, 20, 12], 9001, "fp-stable");
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(to_bytes(&a), to_bytes(&b));

    // different weights (seed) or a different name → different hash
    let c = test_program(&[16, 20, 12], 9002, "fp-stable");
    assert_ne!(a.fingerprint(), c.fingerprint());
    let d = test_program(&[16, 20, 12], 9001, "fp-stable-2");
    assert_ne!(a.fingerprint(), d.fingerprint());

    // the fingerprint covers every byte of the canonical encoding:
    // flipping any single byte must change it (spot-check a spread)
    let bytes = to_bytes(&a);
    let fp = fingerprint_bytes(&bytes);
    assert_eq!(fp, a.fingerprint());
    for frac in [0, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
        let mut mutated = bytes.clone();
        mutated[frac] ^= 0x40;
        assert_ne!(fingerprint_bytes(&mutated), fp, "flip at byte {frac} went unnoticed");
    }

    // and it survives the artifact round-trip (save → load → same hash)
    let path = std::env::temp_dir().join(format!("apu-fp-{}.apu", std::process::id()));
    a.save(&path).unwrap();
    let loaded = Program::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded.fingerprint(), a.fingerprint());
}

#[test]
fn n_shards_pay_exactly_one_plan_build() {
    let program = Arc::new(test_program(&[16, 24, 12], 9100, "one-build"));
    let cfg = test_cfg();
    let fp = program.fingerprint();
    assert_eq!(plan_cache_builds(fp, &cfg), 0, "key already touched — seed collision?");

    // Resolve the shared plan once (what a ModelCatalog does), then load
    // it onto N machines concurrently — the cache must record exactly one
    // build no matter how many loaders race.
    let plan = shared_plan(&program, &cfg).unwrap();
    assert!(plan.is_some(), "synthetic packed-FC program must be plannable");
    assert_eq!(plan_cache_builds(fp, &cfg), 1);

    let mut rng = Rng::new(77);
    let input: Vec<f32> = (0..program.din).map(|_| rng.normal()).collect();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let program = Arc::clone(&program);
            let cfg = cfg.clone();
            let input = input.clone();
            std::thread::spawn(move || {
                let mut apu = Apu::new(cfg);
                apu.load(program).unwrap();
                assert!(apu.is_planned());
                (apu.run(&input).unwrap(), apu.stats().clone())
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(plan_cache_builds(fp, &cfg), 1, "concurrent loads must share one build");

    // shared-plan outputs and stats are bitwise identical to a private
    // reference-interpreter run — sharing must not perturb the numbers
    let mut refr = Apu::new(cfg.clone());
    refr.load(&*program).unwrap();
    let want = refr.run_reference(&input).unwrap();
    for (out, stats) in &results {
        assert_eq!(out.len(), want.len());
        for (i, (&g, &w)) in out.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "output {i}: {g} vs {w}");
        }
        assert_eq!(stats, refr.stats(), "shared-plan stats diverged from reference");
    }

    // a different machine shape is a different key: its own single build
    let other = ApuConfig { pe_sram_bits: 1 << 15, ..cfg.clone() };
    let mut apu = Apu::new(other.clone());
    apu.load(&*program).unwrap();
    assert_eq!(plan_cache_builds(fp, &other), 1);
    assert_eq!(plan_cache_builds(fp, &cfg), 1, "other-machine build must not touch this key");
}

#[test]
fn mixed_model_fleet_routes_and_reports_per_model() {
    let cfg = test_cfg();
    let mut cat = ModelCatalog::new();
    // distinct output dims make cross-model routing mistakes observable
    let pa = Arc::new(test_program(&[16, 24, 12], 9200, "mix-a"));
    let pb = Arc::new(test_program(&[16, 18, 10], 9201, "mix-b"));
    let (fa, fb) = (pa.fingerprint(), pb.fingerprint());
    let a = cat.add_program("mix-a", Arc::clone(&pa), cfg.clone()).unwrap();
    let b = cat.add_program("mix-b", Arc::clone(&pb), cfg.clone()).unwrap();

    let t0 = std::time::Instant::now();
    let fleet = Fleet::start_catalog(
        FleetConfig {
            shards: 0, // ignored: sized by shards_per_model below
            policy: DispatchPolicy::RoundRobin,
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
            queue_cap: 4096,
            metrics: Arc::new(Registry::new()),
            ..FleetConfig::default()
        },
        Arc::new(cat),
        &[2, 2],
    )
    .unwrap();
    // two shards per model, yet still one plan build per model
    assert_eq!(plan_cache_builds(fa, &cfg), 1);
    assert_eq!(plan_cache_builds(fb, &cfg), 1);

    // 70/30 mixed traffic, interleaved in flight across both groups
    let mut load = SyntheticLoad::new(50_000.0, 23);
    let (mut na, mut nb) = (0u64, 0u64);
    let rxs: Vec<_> = (0..40)
        .map(|i| {
            let m = if i % 10 < 7 { na += 1; a } else { nb += 1; b };
            (m, fleet.submit_to(m, load.next_input(16)).unwrap())
        })
        .collect();
    for (m, rx) in rxs {
        let reply = rx.recv().unwrap();
        assert_eq!(reply.model, m);
        let dout = if m == a { 12 } else { 10 };
        assert_eq!(reply.output.unwrap().len(), dout);
        let group = &fleet.groups()[m.0];
        assert!(group.shard_ids().contains(&reply.shard), "reply from a foreign group's shard");
    }

    let m = fleet.shutdown().unwrap();
    let report = SloReport::from_metrics(&m, t0.elapsed());
    assert_eq!(report.per_model.len(), 2);
    let (ref name_a, ref slo_a) = report.per_model[a.0];
    let (ref name_b, ref slo_b) = report.per_model[b.0];
    assert_eq!((name_a.as_str(), name_b.as_str()), ("mix-a", "mix-b"));
    // per-model rows are disjoint group aggregates that sum to the fleet
    assert_eq!(slo_a.completed, na);
    assert_eq!(slo_b.completed, nb);
    assert_eq!(slo_a.completed + slo_b.completed, report.fleet.completed);
    assert_eq!(report.fleet.failed + report.fleet.rejected, 0);
    let rendered = report.render();
    assert!(rendered.contains("per-model:") && rendered.contains("mix-a"), "{rendered}");
}
