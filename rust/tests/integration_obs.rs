//! Observability acceptance: the simulator's per-phase profile must sum
//! to exactly the figures `SimStats` reports, the fleet's metrics
//! registry must agree with the dispatcher's own accounting, and both
//! export formats (Prometheus text, Chrome trace-event JSON) must be
//! well-formed enough to round-trip through a parser.

use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use apu::compiler::emit::{compile_packed_layers, synthetic_packed_network};
use apu::compiler::{pipeline, CostModel, PipelineOptions};
use apu::coordinator::{
    ApuEngine, BatchPolicy, DispatchPolicy, Engine, Fleet, FleetConfig, SloReport, SubmitError,
    SyntheticLoad,
};
use apu::nn::zoo;
use apu::obs::metrics::Registry;
use apu::obs::trace::Tracer;
use apu::sim::{Apu, ApuConfig, SimProfile, SimStats};
use apu::util::json::Json;
use apu::util::rng::Rng;

/// Compile a zoo network, run it with profiling, and return the profile
/// plus the simulator's own stats and the per-layer names.
fn profiled_run(
    net: &apu::nn::Network,
    model: &CostModel,
    runs: usize,
) -> (SimProfile, SimStats, Vec<String>) {
    let compiled = pipeline::compile_network(net, model, &PipelineOptions::default()).unwrap();
    let mut sim = Apu::new(model.apu_config());
    sim.load(&compiled.program).unwrap();
    sim.enable_profiling();
    let mut rng = Rng::new(99);
    for _ in 0..runs {
        let x: Vec<f32> = (0..compiled.program.din).map(|_| rng.uniform(-1.0, 1.0)).collect();
        sim.run(&x).unwrap();
    }
    let stats = sim.stats().clone();
    let profile = sim.take_profile().unwrap();
    let names = compiled.cost.layers.iter().map(|l| l.name.clone()).collect();
    (profile, stats, names)
}

/// The acceptance invariant: profile totals are *exactly* (bitwise, for
/// the f64 energy fields) the stats the simulator reports — for both
/// reference networks, including alexnet-nano's §4.4.3-II host folds.
#[test]
fn profile_totals_equal_simstats_exactly() {
    let model = CostModel::nano_4pe();
    for (net, runs) in [(zoo::alexnet_nano(), 2), (zoo::vgg_nano(), 3)] {
        let (profile, stats, _) = profiled_run(&net, &model, runs);
        profile.check_against(&stats).unwrap_or_else(|e| {
            panic!("{}: profile diverged from SimStats: {e:#}", net.name);
        });
        assert_eq!(profile.totals().inferences, runs as u64, "{}", net.name);
        // the per-layer decomposition also covers every cycle
        let by_layer = profile.by_layer();
        let cycles: u64 = by_layer.values().map(|s| s.total_cycles()).sum();
        assert_eq!(cycles, stats.total_cycles(), "{}: per-layer cycle sum", net.name);
        let pj: f64 = by_layer.values().map(|s| s.total_pj()).sum();
        assert!((pj - stats.total_pj()).abs() < 1e-6 * stats.total_pj().max(1.0), "{}", net.name);
    }
}

#[test]
fn profile_table_names_layers_and_round_trips_as_chrome_trace() {
    let model = CostModel::nano_4pe();
    let (profile, stats, names) = profiled_run(&zoo::vgg_nano(), &model, 1);
    let table = profile.table(&names);
    for name in &names {
        assert!(table.contains(name.as_str()), "table missing layer {name}:\n{table}");
    }
    assert!(table.contains("TOTAL"), "{table}");

    let clock = model.apu_config().clock_ghz;
    let json = profile.chrome_trace(clock).pretty();
    let parsed = Json::parse(&json).unwrap();
    let events = match parsed.path("traceEvents") {
        Some(Json::Arr(evs)) => evs,
        other => panic!("traceEvents missing: {other:?}"),
    };
    assert!(!events.is_empty());
    let mut last_ts = f64::NEG_INFINITY;
    for ev in events {
        let ts = ev.get("ts").and_then(|t| t.as_f64()).unwrap();
        assert!(ts >= last_ts, "trace not sorted by ts");
        last_ts = ts;
        assert!(ev.get("dur").and_then(|d| d.as_f64()).unwrap() >= 0.0);
        assert_eq!(ev.get("ph"), Some(&Json::Str("X".into())));
    }
    // total simulated time appears on the trace's clock mapping: the
    // last event must end within the run's total cycles
    let end_us = stats.total_cycles() as f64 / (clock * 1e3);
    assert!(last_ts <= end_us + 1e-6);
}

/// An engine that blocks until released (to force rejections) — the
/// registry's counters must match the dispatcher's accounting exactly.
#[test]
fn fleet_registry_agrees_with_dispatcher_accounting() {
    struct Stalled(mpsc::Receiver<()>);
    impl Engine for Stalled {
        fn name(&self) -> &str {
            "stalled"
        }
        fn input_dim(&self) -> usize {
            1
        }
        fn output_dim(&self) -> usize {
            1
        }
        fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
            let _ = self.0.recv();
            Ok(inputs.to_vec())
        }
    }
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let gate = Mutex::new(Some(gate_rx));
    let reg = Arc::new(Registry::new());
    let fleet = Fleet::start(
        FleetConfig {
            shards: 1,
            policy: DispatchPolicy::JoinShortestQueue,
            batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(1) },
            queue_cap: 4,
            metrics: Arc::clone(&reg),
            ..FleetConfig::default()
        },
        move |_| Ok(Box::new(Stalled(gate.lock().unwrap().take().unwrap())) as Box<dyn Engine>),
    )
    .unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..32 {
        match fleet.submit(vec![0.25]) {
            Ok(rx) => accepted.push(rx),
            Err(SubmitError::Rejected { shard, depth, cap }) => {
                assert_eq!(shard, 0);
                assert_eq!(cap, 4);
                assert!(depth >= cap);
                rejected += 1;
            }
            Err(e) => panic!("unexpected {e}"),
        }
    }
    assert!(rejected > 0, "saturation must reject");
    for _ in 0..accepted.len() {
        let _ = gate_tx.send(());
    }
    for rx in &accepted {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.completed(), accepted.len() as u64);
    assert_eq!(m.rejected(), rejected);
    // registry == dispatcher, counter for counter
    assert_eq!(reg.counter_total("apu_fleet_completed_total"), m.completed());
    assert_eq!(reg.counter_total("apu_fleet_rejected_total"), m.rejected());
    assert_eq!(reg.counter_total("apu_fleet_enqueued_total"), accepted.len() as u64);
    assert_eq!(reg.counter_total("apu_fleet_engine_errors_total"), 0);
}

/// A healthy multi-shard run: per-shard registry counters sum to the
/// fleet totals, the SLO export lands in the same registry, and the
/// Prometheus exposition is structurally valid (cumulative buckets).
#[test]
fn fleet_metrics_export_prometheus_and_json() {
    let reg = Arc::new(Registry::new());
    let fleet = Fleet::start(
        FleetConfig {
            shards: 2,
            policy: DispatchPolicy::RoundRobin,
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
            queue_cap: 1024,
            metrics: Arc::clone(&reg),
            ..FleetConfig::default()
        },
        |shard| {
            let layers = synthetic_packed_network(&[64, 40, 12], 4, 4, 300 + shard as u64)?;
            let program = compile_packed_layers("obs-it", &layers, 0.15, 4, 4)?;
            let sim = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
            Ok(Box::new(ApuEngine::new(sim, &program)?) as Box<dyn Engine>)
        },
    )
    .unwrap();
    let t0 = std::time::Instant::now();
    let mut load = SyntheticLoad::new(1e6, 31);
    let n = 40u64;
    let rxs: Vec<_> = (0..n).map(|_| fleet.submit(load.next_input(64)).unwrap()).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let m = fleet.shutdown().unwrap();
    assert_eq!(m.completed(), n);
    assert_eq!(reg.counter_total("apu_fleet_completed_total"), n);
    // per-shard series (model-labelled) match per-shard dispatcher accounting
    for (i, sh) in m.shards.iter().enumerate() {
        let s = i.to_string();
        let got = reg.counter_value(
            "apu_fleet_completed_total",
            &[("model", "default"), ("shard", s.as_str())],
        );
        assert_eq!(got, sh.completed, "shard {i}");
    }
    // one engine run_batch call per flushed batch, no more: the engine
    // call counter equals total flushes (by reason) and the batch-size
    // histogram's sample count, per shard and in total
    let flushes = reg.counter_total("apu_fleet_batch_full_flush_total")
        + reg.counter_total("apu_fleet_batch_deadline_flush_total")
        + reg.counter_total("apu_fleet_batch_drain_flush_total");
    let engine_calls = reg.counter_total("apu_fleet_engine_calls_total");
    assert_eq!(engine_calls, flushes);
    assert!(engine_calls > 0 && engine_calls <= n);
    let text_pre = reg.render_prometheus();
    let mut hist_count = 0u64;
    for i in 0..m.shards.len() {
        let line = format!("apu_fleet_batch_size_count{{model=\"default\",shard=\"{i}\"}} ");
        let c: u64 = text_pre
            .lines()
            .find_map(|l| l.strip_prefix(line.as_str()))
            .expect("batch-size histogram series")
            .parse()
            .unwrap();
        hist_count += c;
    }
    assert_eq!(hist_count, engine_calls);
    let report = SloReport::from_metrics(&m, t0.elapsed());
    report.export(&reg);

    let text = reg.render_prometheus();
    assert!(text.contains("# TYPE apu_fleet_completed_total counter"), "{text}");
    assert!(text.contains("# TYPE apu_fleet_request_latency_us histogram"), "{text}");
    assert!(text.contains("apu_slo_p99_us{shard=\"fleet\"}"), "{text}");
    // the per-model SLO aggregate is exported alongside the shard rows
    assert!(text.contains("apu_slo_p99_us{model=\"default\"}"), "{text}");
    // bucket cumulativity for shard 0's latency histogram: counts never
    // decrease and the +Inf bucket equals the series count (labels are
    // sorted, with `le` always last)
    let prefix = "apu_fleet_request_latency_us_bucket{model=\"default\",shard=\"0\",le=\"";
    let mut prev = 0u64;
    let mut last = 0u64;
    let mut saw_inf = false;
    for line in text.lines().filter(|l| l.starts_with(prefix)) {
        let (le, count) = line[prefix.len()..].split_once("\"} ").unwrap();
        let count: u64 = count.parse().unwrap();
        assert!(count >= prev, "bucket le={le} went backwards: {count} < {prev}");
        prev = count;
        last = count;
        saw_inf |= le == "+Inf";
    }
    assert!(saw_inf, "no +Inf bucket:\n{text}");
    let count_line =
        format!("apu_fleet_request_latency_us_count{{model=\"default\",shard=\"0\"}} {last}");
    assert!(text.contains(&count_line), "count != +Inf bucket:\n{text}");

    // the JSON dump parses back and carries the same totals
    let parsed = Json::parse(&reg.to_json().pretty()).unwrap();
    let fam = parsed.get("apu_fleet_completed_total").expect("family in JSON dump");
    assert_eq!(fam.path("kind"), Some(&Json::Str("counter".into())));
}

/// Compiler pass spans and fleet request spans land in one tracer and
/// export as a single, sorted, parseable Chrome trace.
#[test]
fn compiler_and_fleet_spans_share_one_chrome_trace() {
    let tracer = Tracer::new();
    let model = CostModel::nano_4pe();
    let opts = PipelineOptions { tracer: Some(tracer.clone()), ..Default::default() };
    let compiled = pipeline::compile_network(&zoo::vgg_nano(), &model, &opts).unwrap();
    let din = compiled.program.din;

    let reg = Arc::new(Registry::new());
    let fleet = Fleet::start(
        FleetConfig {
            shards: 1,
            policy: DispatchPolicy::RoundRobin,
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
            queue_cap: 1024,
            metrics: reg,
            tracer: Some(tracer.clone()),
            ..FleetConfig::default()
        },
        move |_| Ok(Box::new(ApuEngine::from_compiled(&compiled)?) as Box<dyn Engine>),
    )
    .unwrap();
    let mut load = SyntheticLoad::new(1e6, 17);
    let rxs: Vec<_> = (0..8).map(|_| fleet.submit(load.next_input(din)).unwrap()).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    fleet.shutdown().unwrap();

    let events = tracer.events();
    for want in ["normalize", "decide_layer", "compress", "emit", "request", "engine-run"] {
        assert!(events.iter().any(|e| e.name == want), "missing span {want}");
    }
    let parsed = Json::parse(&tracer.chrome_trace().pretty()).unwrap();
    let Some(Json::Arr(evs)) = parsed.path("traceEvents") else {
        panic!("traceEvents missing");
    };
    assert_eq!(evs.len(), events.len());
    let mut last_ts = f64::NEG_INFINITY;
    for ev in evs {
        let ts = ev.get("ts").and_then(|t| t.as_f64()).unwrap();
        assert!(ts >= last_ts, "events must be ts-sorted");
        last_ts = ts;
    }
    // request spans carry the enqueue→reply pipeline timestamps
    let req = evs
        .iter()
        .find(|e| e.get("name") == Some(&Json::Str("request".into())))
        .expect("a request span");
    for key in ["enqueue_us", "dequeue_us", "engine_start_us", "engine_end_us", "reply_us"] {
        assert!(req.path(&format!("args/{key}")).is_some(), "request span missing {key}");
    }
}
