//! End-to-end serving: coordinator + batcher + APU-sim engine under load.

use std::time::Duration;

use apu::compiler::emit::{compile_packed_layers, synthetic_packed_network};
use apu::coordinator::{ApuEngine, BatchPolicy, Engine, Server, SyntheticLoad};
use apu::sim::{Apu, ApuConfig};

fn make_engine() -> anyhow::Result<Box<dyn Engine>> {
    let layers = synthetic_packed_network(&[64, 40, 12], 4, 4, 99)?;
    let program = compile_packed_layers("srv", &layers, 0.15, 4, 4)?;
    let apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
    Ok(Box::new(ApuEngine::new(apu, &program)?))
}

#[test]
fn sustained_load_completes_every_request() {
    let server = Server::start(
        make_engine,
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    let mut load = SyntheticLoad::new(5000.0, 4);
    let n = 200;
    let rxs: Vec<_> = (0..n).map(|_| server.submit(load.next_input(64)).unwrap()).collect();
    let mut ok = 0;
    for rx in rxs {
        let reply = rx.recv().unwrap();
        assert_eq!(reply.output.unwrap().len(), 12);
        ok += 1;
    }
    assert_eq!(ok, n);
    let mut metrics = server.shutdown().unwrap();
    assert_eq!(metrics.completed, n as u64);
    assert!(metrics.batch_sizes.mean() > 1.0, "bursty load should batch");
    assert!(metrics.latency_us.p99() >= metrics.latency_us.median());
}

#[test]
fn deterministic_outputs_regardless_of_batching() {
    // The same input must produce the same output whether it rides a
    // batch of 1 or a burst (no cross-request state leaks).
    let solo = Server::start(
        make_engine,
        BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
    )
    .unwrap();
    let input: Vec<f32> = (0..64).map(|i| ((i * 7 % 15) as f32 - 7.0) * 0.1).collect();
    let want = solo.infer(input.clone()).unwrap().into_output().unwrap();
    solo.shutdown().unwrap();

    let batched = Server::start(
        make_engine,
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
    )
    .unwrap();
    let mut load = SyntheticLoad::new(1e9, 5);
    let mut rxs = Vec::new();
    for i in 0..16 {
        let x = if i == 7 { input.clone() } else { load.next_input(64) };
        rxs.push((i, batched.submit(x).unwrap()));
    }
    for (i, rx) in rxs {
        let reply = rx.recv().unwrap();
        if i == 7 {
            assert_eq!(reply.output.unwrap(), want);
        }
    }
    batched.shutdown().unwrap();
}

#[test]
fn failed_engine_construction_surfaces() {
    let r = Server::start(
        || anyhow::bail!("boom"),
        BatchPolicy::default(),
    );
    assert!(r.is_err());
}

#[test]
fn server_drains_queue_on_shutdown() {
    let server = Server::start(
        make_engine,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
    )
    .unwrap();
    let mut load = SyntheticLoad::new(1e9, 6);
    let rxs: Vec<_> = (0..10).map(|_| server.submit(load.next_input(64)).unwrap()).collect();
    let metrics = server.shutdown().unwrap(); // must flush pending work
    assert_eq!(metrics.completed, 10);
    for rx in rxs {
        assert!(rx.recv().is_ok());
    }
}
