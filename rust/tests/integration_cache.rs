//! Request-level result cache end to end: cache-hit replies must be
//! bitwise identical to engine replies on every compilable zoo network ×
//! both machine instances × lane-pool widths {1, 4} — anchored on the
//! planner's input-determinism contract (integration_plan.rs). The rest
//! of the matrix: a hot key hammered from N threads pays exactly one
//! miss, capacity-1 LRU evicts deterministically, a disabled cache is
//! byte-identical to the uncached fleet, hits leave the JSQ queue signal
//! and every per-shard metric untouched (the accounting rule), and
//! entries never leak across ModelIds even for same-shaped inputs.

use std::sync::Arc;
use std::time::Duration;

use apu::compiler::pipeline::{compile_network, PipelineOptions};
use apu::compiler::{compile_packed_layers, synthetic_packed_network, CostModel};
use apu::coordinator::{
    BatchPolicy, DispatchPolicy, Fleet, FleetConfig, ModelCatalog, ModelId, CACHE_SHARD,
};
use apu::nn::zoo;
use apu::obs::metrics::Registry;
use apu::sim::{Apu, ApuConfig};
use apu::util::rng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn config(threads: usize, cache_entries: usize, reg: Arc<Registry>) -> FleetConfig {
    FleetConfig {
        shards: 0, // sized by shards_per_model at start_catalog
        policy: DispatchPolicy::JoinShortestQueue,
        batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
        queue_cap: 4096,
        metrics: reg,
        threads_per_shard: threads,
        cache_entries,
        ..FleetConfig::default()
    }
}

fn synth_catalog(models: &[(&str, &[usize], u64)]) -> (ModelCatalog, ApuConfig) {
    let cfg = ApuConfig { n_pes: 4, pe_sram_bits: 1 << 20, clock_ghz: 1.0 };
    let mut cat = ModelCatalog::new();
    for (name, dims, seed) in models {
        let layers = synthetic_packed_network(dims, 4, 4, *seed).unwrap();
        let program = compile_packed_layers(name, &layers, 0.15, 4, 4).unwrap();
        cat.add_program(name, Arc::new(program), cfg.clone()).unwrap();
    }
    (cat, cfg)
}

/// The centerpiece: on every compilable zoo network × both machines ×
/// lane widths {1, 4}, a cold submission must match a directly-driven
/// planned Apu bit-for-bit, and the warm resubmission must be served
/// from the cache (shard = CACHE_SHARD, batch_size = 0) with the exact
/// same bits. All models share one mixed catalog per fleet, so routing
/// and keying are exercised together. Also pins the ±0.0 canonicalization
/// soundness: an all-(−0.0) request may be served from the all-(+0.0)
/// entry, so the engine's outputs for the two inputs must be bitwise
/// equal (the sign of zero dies at the first accumulation).
#[test]
fn cache_hits_are_bitwise_identical_across_the_zoo() {
    let machines = [("paper_9pe", CostModel::paper_9pe()), ("nano_4pe", CostModel::nano_4pe())];
    let mut executed: Vec<String> = Vec::new();
    for (mname, model) in &machines {
        // the big paper networks are analytic-only on these instances;
        // the cache contract covers whatever actually compiles
        let mut programs: Vec<(String, Arc<apu::isa::Program>)> = Vec::new();
        for name in zoo::names() {
            let net = zoo::by_name(name).unwrap();
            let Ok(compiled) = compile_network(&net, model, &PipelineOptions::default()) else {
                continue;
            };
            programs.push((name.to_string(), Arc::new(compiled.program)));
            executed.push(format!("{mname}/{name}"));
        }
        for threads in [1usize, 4] {
            let mut cat = ModelCatalog::new();
            for (name, prog) in &programs {
                cat.add_program(name, Arc::clone(prog), model.apu_config()).unwrap();
            }
            let reg = Arc::new(Registry::new());
            let fleet = Fleet::start_catalog(
                config(threads, 128, Arc::clone(&reg)),
                Arc::new(cat),
                &vec![1; programs.len()],
            )
            .unwrap();
            for (m, (name, prog)) in programs.iter().enumerate() {
                let id = ModelId(m);
                let mut refr = Apu::new(model.apu_config());
                refr.load(Arc::clone(prog)).unwrap();
                let mut rng = Rng::new(4000 + m as u64);
                for k in 0..2 {
                    let x: Vec<f32> = (0..prog.din).map(|_| rng.normal()).collect();
                    let want = bits(&refr.run(&x).unwrap());

                    let cold = fleet.submit_to(id, x.clone()).unwrap().recv().unwrap();
                    assert!(!cold.cached, "{mname}/{name} t{threads} input {k}: cold hit?");
                    assert_eq!(
                        bits(&cold.output.unwrap()),
                        want,
                        "{mname}/{name} t{threads} input {k}: engine reply != direct run"
                    );

                    let hot = fleet.submit_to(id, x).unwrap().recv().unwrap();
                    assert!(hot.cached, "{mname}/{name} t{threads} input {k}: repeat missed");
                    assert_eq!(hot.shard, CACHE_SHARD);
                    assert_eq!(hot.batch_size, 0, "hits must not claim batch work");
                    assert_eq!(hot.model, id);
                    let served = hot.output.unwrap();
                    assert_eq!(served.len(), prog.dout);
                    assert_eq!(
                        bits(&served),
                        want,
                        "{mname}/{name} t{threads} input {k}: cached reply != direct run"
                    );
                }

                // ±0.0 soundness, empirically: whatever the keyer decides
                // (hit via the collapsed key, or miss on the raw-bits
                // fallback), the served bits must equal the +0.0 run's.
                let plus = vec![0.0f32; prog.din];
                let zp = fleet.submit_to(id, plus.clone()).unwrap().recv().unwrap();
                let zm = fleet.submit_to(id, vec![-0.0f32; prog.din]).unwrap().recv().unwrap();
                let zero_bits = bits(&zp.output.unwrap());
                assert_eq!(
                    bits(&zm.output.unwrap()),
                    zero_bits,
                    "{mname}/{name} t{threads}: -0.0 input diverged from +0.0"
                );
                assert_eq!(bits(&refr.run(&plus).unwrap()), zero_bits);
            }
            assert!(reg.counter_total("apu_fleet_cache_hits_total") > 0);
            fleet.shutdown().unwrap();
        }
    }
    assert!(executed.contains(&"nano_4pe/vgg-nano".to_string()), "executed: {executed:?}");
    assert!(executed.contains(&"nano_4pe/alexnet-nano".to_string()), "executed: {executed:?}");
    assert!(executed.contains(&"paper_9pe/lenet".to_string()), "executed: {executed:?}");
}

/// N threads hammering one warmed key are all served from the cache: one
/// miss total, one engine call total, and every reply carries the warm
/// run's exact bits.
#[test]
fn a_hot_key_hammered_from_many_threads_pays_one_miss() {
    let (cat, _) = synth_catalog(&[("hot", &[16usize, 20, 12][..], 5100)]);
    let reg = Arc::new(Registry::new());
    let fleet =
        Fleet::start_catalog(config(1, 64, Arc::clone(&reg)), Arc::new(cat), &[2]).unwrap();
    let input: Vec<f32> = {
        let mut rng = Rng::new(1);
        (0..16).map(|_| rng.normal()).collect()
    };
    let warm = fleet.submit_to(ModelId(0), input.clone()).unwrap().recv().unwrap();
    assert!(!warm.cached);
    let want = bits(&warm.output.unwrap());

    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                for _ in 0..50 {
                    let r = fleet.submit_to(ModelId(0), input.clone()).unwrap().recv().unwrap();
                    assert!(r.cached && r.shard == CACHE_SHARD);
                    assert_eq!(bits(&r.output.unwrap()), want);
                }
            });
        }
    });

    assert_eq!(reg.counter_total("apu_fleet_cache_misses_total"), 1);
    assert_eq!(reg.counter_total("apu_fleet_cache_hits_total"), 400);
    // the accounting rule: only the warm-up ever reached a shard
    assert_eq!(reg.counter_total("apu_fleet_enqueued_total"), 1);
    assert_eq!(reg.counter_total("apu_fleet_engine_calls_total"), 1);
    let m = fleet.shutdown().unwrap();
    let stats = m.cache[0].clone().unwrap();
    assert_eq!((stats.hits, stats.misses, stats.entries), (400, 1, 1));
}

/// A per-model capacity-1 override (ModelCatalog::set_cache_entries)
/// gives a single-shard exact-LRU cache whose eviction order is fully
/// deterministic under serialized traffic.
#[test]
fn capacity_one_override_evicts_deterministically() {
    let (mut cat, _) = synth_catalog(&[("tiny", &[16usize, 20, 12][..], 5200)]);
    cat.set_cache_entries(ModelId(0), Some(1)).unwrap();
    let reg = Arc::new(Registry::new());
    // fleet default says "no cache"; the entry's override wins
    let fleet = Fleet::start_catalog(config(1, 0, Arc::clone(&reg)), Arc::new(cat), &[1]).unwrap();
    let mut rng = Rng::new(2);
    let in1: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
    let in2: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
    let go = |x: &Vec<f32>| fleet.submit_to(ModelId(0), x.clone()).unwrap().recv().unwrap();

    assert!(!go(&in1).cached); // miss 1: fills the single slot
    assert!(go(&in1).cached); // hit 1
    assert!(!go(&in2).cached); // miss 2: evicts in1
    assert!(!go(&in1).cached); // miss 3: evicts in2
    assert!(go(&in1).cached); // hit 2

    let m = fleet.shutdown().unwrap();
    let stats = m.cache[0].clone().unwrap();
    assert_eq!((stats.hits, stats.misses, stats.evictions), (2, 3, 2));
    assert_eq!((stats.entries, stats.capacity), (1, 1));
}

/// cache_entries = 0 and no per-model override: no cache series exist,
/// no reply is ever marked cached, and repeated inputs still reproduce
/// the direct planned run bit-for-bit (the pre-cache contract).
#[test]
fn disabled_cache_serves_bitwise_identical_replies() {
    let cfg = ApuConfig { n_pes: 4, pe_sram_bits: 1 << 20, clock_ghz: 1.0 };
    let layers = synthetic_packed_network(&[16, 20, 12], 4, 4, 5300).unwrap();
    let program = Arc::new(compile_packed_layers("plain", &layers, 0.15, 4, 4).unwrap());
    let mut cat = ModelCatalog::new();
    cat.add_program("plain", Arc::clone(&program), cfg.clone()).unwrap();
    let reg = Arc::new(Registry::new());
    let fleet = Fleet::start_catalog(config(1, 0, Arc::clone(&reg)), Arc::new(cat), &[1]).unwrap();

    let mut refr = Apu::new(cfg);
    refr.load(Arc::clone(&program)).unwrap();
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
    let want = bits(&refr.run(&x).unwrap());
    for _ in 0..2 {
        let r = fleet.submit_to(ModelId(0), x.clone()).unwrap().recv().unwrap();
        assert!(!r.cached && r.shard != CACHE_SHARD);
        assert_eq!(bits(&r.output.unwrap()), want);
    }
    assert_eq!(reg.counter_total("apu_fleet_cache_hits_total"), 0);
    assert_eq!(reg.counter_total("apu_fleet_cache_misses_total"), 0);
    let m = fleet.shutdown().unwrap();
    assert!(m.cache.is_empty(), "uncached fleet must not report cache stats");
}

/// The accounting rule, measured: a burst of hits moves only the
/// apu_fleet_cache_* series. Enqueued/engine-call counters, the whole
/// batch-size histogram family, and the JSQ load snapshot (queued and
/// outstanding per shard) stay exactly where the warm-up left them.
#[test]
fn hits_leave_shard_metrics_and_the_jsq_signal_untouched() {
    let (cat, _) = synth_catalog(&[("signal", &[16usize, 20, 12][..], 5400)]);
    let reg = Arc::new(Registry::new());
    let fleet =
        Fleet::start_catalog(config(1, 128, Arc::clone(&reg)), Arc::new(cat), &[2]).unwrap();
    let batch_family = |reg: &Registry| -> String {
        reg.render_prometheus()
            .lines()
            .filter(|l| l.contains("apu_fleet_batch_size"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let mut rng = Rng::new(4);
    let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
    assert!(!fleet.submit_to(ModelId(0), x.clone()).unwrap().recv().unwrap().cached);

    let enq0 = reg.counter_total("apu_fleet_enqueued_total");
    let calls0 = reg.counter_total("apu_fleet_engine_calls_total");
    let hist0 = batch_family(&reg);
    assert!(hist0.contains("apu_fleet_batch_size"), "warm-up produced no batch histogram");

    for _ in 0..100 {
        assert!(fleet.submit_to(ModelId(0), x.clone()).unwrap().recv().unwrap().cached);
    }

    assert_eq!(reg.counter_total("apu_fleet_enqueued_total"), enq0);
    assert_eq!(reg.counter_total("apu_fleet_engine_calls_total"), calls0);
    assert_eq!(batch_family(&reg), hist0, "hits leaked into the batch-size histogram");
    for (i, load) in fleet.shard_loads().iter().enumerate() {
        assert_eq!((load.queued, load.outstanding), (0, 0), "shard {i} saw cache traffic");
    }
    assert_eq!(reg.counter_total("apu_fleet_cache_hits_total"), 100);
    fleet.shutdown().unwrap();
}

/// Same-shaped inputs to different models never share entries: the key
/// carries the program fingerprint, so each model's hit returns its own
/// output (observable here through the distinct output dims).
#[test]
fn identical_inputs_never_leak_across_models() {
    let (cat, _) =
        synth_catalog(&[("wide", &[16usize, 20, 12][..], 5500), ("narrow", &[16, 18, 10][..], 5501)]);
    let reg = Arc::new(Registry::new());
    let fleet =
        Fleet::start_catalog(config(1, 64, Arc::clone(&reg)), Arc::new(cat), &[1, 1]).unwrap();
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();

    let mut cold_bits = Vec::new();
    for (m, dout) in [(0usize, 12usize), (1, 10)] {
        let cold = fleet.submit_to(ModelId(m), x.clone()).unwrap().recv().unwrap();
        assert!(!cold.cached, "model {m}: first submission hit a foreign entry");
        let out = cold.output.unwrap();
        assert_eq!(out.len(), dout);
        cold_bits.push(bits(&out));
    }
    for (m, dout) in [(0usize, 12usize), (1, 10)] {
        let hot = fleet.submit_to(ModelId(m), x.clone()).unwrap().recv().unwrap();
        assert!(hot.cached && hot.model == ModelId(m));
        let out = hot.output.unwrap();
        assert_eq!(out.len(), dout, "model {m}: hit served a foreign model's output");
        assert_eq!(bits(&out), cold_bits[m]);
    }
    let m = fleet.shutdown().unwrap();
    for (i, stats) in m.cache.iter().enumerate() {
        let s = stats.clone().unwrap();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1), "group {i}: {s:?}");
    }
}
