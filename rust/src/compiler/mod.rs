//! Network compiler: high-level models → APU programs (paper §4.2, Fig. 8).
//!
//! The paper's flow parses a TensorFlow/Caffe model, extracts weights and
//! activations, and translates the model into accelerator instructions.
//! Ours is the same pipeline with the python bundle as the interchange:
//!
//! * [`import_`] — load the python-exported packed model (INT4 codes,
//!   scales, permutations) into [`crate::pruning::PackedLayer`]s;
//! * [`emit`] — lower packed layers into an executable [`crate::isa::Program`]:
//!   per-layer routing schedules, wave folding when blocks exceed PEs,
//!   host ops for ingress quantization;
//! * [`cost`] — the analytic mapping/cost model for whole networks
//!   (conv cases I–III of §4.4.3, pooling on host, attention per head):
//!   produces per-layer cycle/energy/utilization without functional
//!   simulation, validated against the cycle-accurate sim on small FC
//!   networks (`rust/tests/integration_sim.rs`).

pub mod cost;
pub mod emit;
pub mod import_;

pub use cost::{CostModel, LayerCost, MappingCase, NetworkCost};
pub use emit::{compile_packed_layers, synthetic_packed_network};
pub use import_::import_bundle;
