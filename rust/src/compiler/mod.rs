//! Network compiler: high-level models → APU programs (paper §4.2, Fig. 8).
//!
//! The paper's flow parses a TensorFlow/Caffe model, extracts weights and
//! activations, and translates the model into accelerator instructions.
//! Ours is the same flow, staged as passes:
//!
//! * [`pipeline`] — the pass-based graph pipeline: any
//!   [`crate::nn::Network`] + machine model → executable
//!   [`crate::isa::Program`] (normalize → weights/fold → map → lower →
//!   emit). Convs lower via im2col-style unrolling (§4.4.3 cases I/III),
//!   pooling/padding run as host ops, FCs get structured pruning + INT-k
//!   quantization.
//! * [`cost`] — the analytic mapping/cost model for whole networks.
//!   [`cost::decide_layer`] is the *shared* mapping decision: the
//!   pipeline emitter and the cost model consume the same
//!   [`cost::MappingDecision`] per layer, so predictions and programs
//!   agree on every layer's §4.4.3 case (cross-validated in
//!   `rust/tests/integration_sim.rs` and
//!   `rust/tests/integration_pipeline.rs`).
//! * [`emit`] — the packed-FC emitter: per-layer routing schedules, wave
//!   folding when blocks exceed PEs, host ops for ingress quantization
//!   (used directly for imported FC stacks, and by the pipeline for FC
//!   layers).
//! * [`import_`] — load the python-exported packed model (INT4 codes,
//!   scales, permutations) into [`crate::pruning::PackedLayer`]s.

pub mod cost;
pub mod emit;
pub mod import_;
pub mod pipeline;

pub use cost::{decide_layer, CostModel, LayerCost, MappingCase, MappingDecision, NetworkCost};
pub use emit::{compile_packed_layers, synthetic_packed_network};
pub use import_::import_bundle;
pub use pipeline::{analyze, compile_network, CompiledNetwork, NetworkAnalysis, PipelineOptions};
