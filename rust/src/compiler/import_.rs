//! Import the python-exported model bundle (the TF/Caffe-parser analogue
//! of paper Fig. 8: "parsing the model to extract the activation and
//! weight parameters").

use anyhow::{bail, Context, Result};

use crate::pruning::{BlockStructure, PackedLayer};
use crate::util::bundle::Bundle;
use crate::util::json::Json;

/// The imported model: packed layers + ingress scale, ready for
/// [`crate::compiler::emit::compile_packed_layers`].
#[derive(Debug)]
pub struct ImportedModel {
    pub name: String,
    pub bits: u32,
    pub in_scale: f32,
    pub layers: Vec<PackedLayer>,
}

/// Load `lenet_model.json`-style bundles.
pub fn import_bundle(manifest_path: &str) -> Result<ImportedModel> {
    let b = Bundle::load(manifest_path)?;
    let bits = b.manifest.get("bits").and_then(Json::as_usize).context("manifest missing bits")? as u32;
    let in_scale = b.manifest.get("in_scale").and_then(Json::as_f64).context("manifest missing in_scale")? as f32;
    let name = b.manifest.get("model").and_then(Json::as_str).unwrap_or("imported").to_string();
    let layer_meta = b.manifest.get("layers").and_then(Json::as_arr).context("manifest missing layers")?;

    let mut layers = Vec::new();
    for (li, meta) in layer_meta.iter().enumerate() {
        let kind = meta.get("kind").and_then(Json::as_str).context("layer missing kind")?;
        let relu = meta.get("relu").and_then(Json::as_bool).unwrap_or(true);
        match kind {
            "block" => {
                let nb = meta.get("nb").and_then(Json::as_usize).context("nb")?;
                let dout = meta.get("dout").and_then(Json::as_usize).context("dout")?;
                let din = meta.get("din").and_then(Json::as_usize).context("din")?;
                let codes_flat = b.tensor(&format!("l{li}.w_codes"))?.as_i8()?;
                let w_scale = b.tensor(&format!("l{li}.w_scale"))?.as_f32()?.to_vec();
                let bias_flat = b.tensor(&format!("l{li}.b"))?.as_f32()?;
                let out_scale = b.tensor(&format!("l{li}.out_scale"))?.as_f32()?.to_vec();
                let col_perm = b.tensor(&format!("l{li}.col_perm"))?.as_u32()?;
                let row_perm = b.tensor(&format!("l{li}.row_perm"))?.as_u32()?;
                let structure = BlockStructure::from_flat_perms(dout, din, nb, row_perm, col_perm)?;
                let (bh, bw) = (structure.bh(), structure.bw());
                if codes_flat.len() != nb * bh * bw {
                    bail!("layer {li}: codes len {} != {nb}x{bh}x{bw}", codes_flat.len());
                }
                if bias_flat.len() != nb * bh {
                    bail!("layer {li}: bias len {} != {nb}x{bh}", bias_flat.len());
                }
                let codes: Vec<Vec<i8>> = codes_flat.chunks(bh * bw).map(|c| c.to_vec()).collect();
                let bias: Vec<Vec<f32>> = bias_flat.chunks(bh).map(|c| c.to_vec()).collect();
                layers.push(PackedLayer { structure, bits, codes, w_scale, bias, out_scale, relu });
            }
            "dense" => {
                // Small unstructured head: one block spanning the layer,
                // quantizer bypassed (out_scale = 0).
                let dout = meta.get("dout").and_then(Json::as_usize).context("dout")?;
                let din = meta.get("din").and_then(Json::as_usize).context("din")?;
                let w_scale = meta.get("w_scale").and_then(Json::as_f64).context("w_scale")? as f32;
                let codes = b.tensor(&format!("l{li}.w_codes"))?.as_i8()?.to_vec();
                let bias = b.tensor(&format!("l{li}.b"))?.as_f32()?.to_vec();
                if codes.len() != dout * din {
                    bail!("layer {li}: dense codes len {} != {dout}x{din}", codes.len());
                }
                let row_perm: Vec<u32> = (0..dout as u32).collect();
                let col_perm: Vec<u32> = (0..din as u32).collect();
                let structure = BlockStructure::from_flat_perms(dout, din, 1, &row_perm, &col_perm)?;
                layers.push(PackedLayer {
                    structure,
                    bits,
                    codes: vec![codes],
                    w_scale: vec![w_scale],
                    bias: vec![bias],
                    out_scale: vec![0.0],
                    relu,
                });
            }
            other => bail!("layer {li}: unknown kind {other}"),
        }
    }
    Ok(ImportedModel { name, bits, in_scale, layers })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The real artifact bundle, when present (built by `make artifacts`).
    fn artifact_path() -> Option<String> {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/lenet_model.json");
        std::path::Path::new(p).exists().then(|| p.to_string())
    }

    #[test]
    fn imports_real_artifact_if_present() {
        let Some(path) = artifact_path() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = import_bundle(&path).unwrap();
        assert_eq!(m.bits, 4);
        assert_eq!(m.layers.len(), 3);
        assert_eq!(m.layers[0].structure.din, 800);
        assert_eq!(m.layers[0].structure.nb, 10);
        assert_eq!(m.layers[2].structure.dout, 10);
        assert_eq!(m.layers[2].out_scale[0], 0.0); // head unquantized
        // forward runs
        let out = m.layers[0].forward(&vec![0.1; 800]).unwrap();
        assert_eq!(out.len(), 300);
    }

    #[test]
    fn missing_file_is_an_error() {
        assert!(import_bundle("/nonexistent/x.json").is_err());
    }
}
