//! Analytic mapping + cost model: whole networks → per-layer cycles and
//! utilization (paper §4.4.3's mapping cases), without functional
//! simulation. Validated against the cycle-accurate simulator on small FC
//! and conv networks (`rust/tests/integration_sim.rs`,
//! `rust/tests/integration_pipeline.rs`).
//!
//! The mapping choice itself lives in [`decide_layer`]: one
//! [`MappingDecision`] per layer that both this analytic model and the
//! executable emitter (`compiler::pipeline`) consume, so the two paths
//! cannot silently diverge on which §4.4.3 case a layer takes.
//!
//! Phases per layer mirror the engine: weight streaming (only when the
//! layer exceeds on-chip residency), activation routing (one value per PE
//! per cycle over the mux crossbar), spatial compute (one output row per
//! PE per cycle), and host-core work (pooling, partial-sum folds).

use anyhow::{bail, Context, Result};

use crate::nn::graph::Shape;
use crate::nn::{LayerKind, Network};

/// Machine parameters for the mapping (a generated design instance).
#[derive(Debug, Clone)]
pub struct CostModel {
    pub n_pes: usize,
    /// PE block capacity: rows × cols (weight SRAM geometry).
    pub pe_h: usize,
    pub pe_w: usize,
    pub bits: u32,
    pub clock_ghz: f64,
    /// Structured-pruning block count for FC layers (density = 1/nb);
    /// `None` = run FCs dense.
    pub fc_blocks: Option<usize>,
    /// Use group convolutions (§4.4.3-III) for conv layers.
    pub group_conv: bool,
    /// DMA bus width for weight streaming, bits per cycle.
    pub dma_bits_per_cycle: u64,
}

impl CostModel {
    /// The Figs. 13–15 configuration: 9 PEs of 513×513 (paper: "fitting
    /// even the largest of convolutions ... onto just 9 513x513 PEs").
    pub fn paper_9pe() -> CostModel {
        CostModel {
            n_pes: 9,
            pe_h: 513,
            pe_w: 513,
            bits: 4,
            clock_ghz: 1.0,
            fc_blocks: Some(10),
            group_conv: true,
            dma_bits_per_cycle: 64,
        }
    }

    /// A small instance for end-to-end executable tests and fleet serving
    /// demos: 4 PEs of 64×128 at INT4 — `zoo::vgg_nano` fits entirely
    /// on-chip and simulates in milliseconds.
    pub fn nano_4pe() -> CostModel {
        CostModel {
            n_pes: 4,
            pe_h: 64,
            pe_w: 128,
            bits: 4,
            clock_ghz: 1.0,
            fc_blocks: Some(4),
            group_conv: true,
            dma_bits_per_cycle: 64,
        }
    }

    /// On-chip weight residency budget, bits.
    pub fn residency_bits(&self) -> u64 {
        (self.n_pes * self.pe_h * self.pe_w) as u64 * self.bits as u64
    }

    /// The simulator machine matching this mapping model (one PE SRAM
    /// holds exactly one `pe_h × pe_w` block at `bits` precision).
    pub fn apu_config(&self) -> crate::sim::ApuConfig {
        crate::sim::ApuConfig {
            n_pes: self.n_pes,
            pe_sram_bits: self.pe_h * self.pe_w * self.bits as usize,
            clock_ghz: self.clock_ghz,
        }
    }
}

/// Which §4.4.3 mapping the compiler chose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingCase {
    /// Structured-pruned FC over nb blocks.
    FcStructured,
    /// Dense FC tiled over the PE array.
    FcDense,
    /// Case I: kernel fits one PE; positions parallelize across PEs.
    ConvSmall,
    /// Case II: kernel split across PEs; host folds partial sums.
    ConvLarge,
    /// Case III: structured-sparse group convolution.
    ConvGroup,
    /// Host-core op (pooling).
    Host,
    /// Folded away at compile time (batch norm).
    Folded,
    /// Multi-head attention: heads map to PEs (§4.4.4).
    Attention,
}

/// The shared per-layer mapping decision (paper §4.4.3): which case the
/// layer takes and the geometry that implies. Produced once by
/// [`decide_layer`] and consumed by *both* the analytic cost model and
/// the executable emitter, so cycle predictions and emitted programs
/// always agree on the mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MappingDecision {
    pub case: MappingCase,
    /// FC structured-pruning block count (1 = dense). 1 for non-FC layers.
    pub nb: usize,
    /// Conv group count actually mapped (1 when `group_conv` is off).
    pub groups: usize,
    /// PE tiling of one block/group: row tiles × column tiles.
    pub th: usize,
    pub tw: usize,
    /// Independent (block/position/tile) mat-vec jobs to schedule.
    pub jobs: u64,
    /// Output rows per job = compute cycles per wave.
    pub tile_rows: u64,
}

impl MappingDecision {
    fn host_only(case: MappingCase) -> MappingDecision {
        MappingDecision { case, nb: 1, groups: 1, th: 0, tw: 0, jobs: 0, tile_rows: 0 }
    }

    /// Executable on the PE array without cross-PE partial-sum folds:
    /// one block/group fits a single PE.
    pub fn fits_one_pe(&self) -> bool {
        self.th == 1 && self.tw == 1
    }
}

/// Map one layer onto the machine (the single source of truth for the
/// §4.4.3 case selection). `inp`/`outp` are the layer's activation shapes.
pub fn decide_layer(model: &CostModel, kind: &LayerKind, inp: Shape, outp: Shape) -> Result<MappingDecision> {
    Ok(match kind {
        LayerKind::Fc { dout } => {
            let din = inp.flat();
            let (case, nb) = match model.fc_blocks {
                Some(nb) if nb > 0 && dout % nb == 0 && din % nb == 0 => (MappingCase::FcStructured, nb),
                _ => (MappingCase::FcDense, 1),
            };
            let (bh, bw) = (dout / nb, din / nb);
            let th = bh.div_ceil(model.pe_h);
            let tw = bw.div_ceil(model.pe_w);
            MappingDecision {
                case,
                nb,
                groups: 1,
                th,
                tw,
                jobs: (nb * th * tw) as u64,
                tile_rows: bh.min(model.pe_h) as u64,
            }
        }
        LayerKind::Conv { cout, kh, kw, groups, .. } => {
            let positions = (outp.h * outp.w) as u64;
            let g = if model.group_conv { (*groups).max(1) } else { 1 };
            let kvol = kh * kw * (inp.c / g); // unrolled kernel cols per group
            let rows_per_group = cout / g;
            let th = rows_per_group.div_ceil(model.pe_h);
            let tw = kvol.div_ceil(model.pe_w);
            let case = if g > 1 {
                MappingCase::ConvGroup
            } else if th == 1 && tw == 1 {
                MappingCase::ConvSmall
            } else {
                MappingCase::ConvLarge
            };
            MappingDecision {
                case,
                nb: 1,
                groups: g,
                th,
                tw,
                jobs: positions * g as u64 * (th * tw) as u64,
                tile_rows: rows_per_group.min(model.pe_h) as u64,
            }
        }
        LayerKind::MaxPool { .. } => MappingDecision::host_only(MappingCase::Host),
        LayerKind::BatchNorm => MappingDecision::host_only(MappingCase::Folded),
        LayerKind::Attention { heads, dk, seq, .. } => {
            if *heads == 0 {
                bail!("zero attention heads");
            }
            MappingDecision {
                case: MappingCase::Attention,
                nb: 1,
                groups: 1,
                th: 1,
                tw: 1,
                jobs: *heads as u64,
                tile_rows: (4 * dk * seq + 2 * seq * seq) as u64,
            }
        }
    })
}

/// Per-layer cost breakdown.
#[derive(Debug, Clone)]
pub struct LayerCost {
    pub name: String,
    pub case: MappingCase,
    pub macs: u64,
    pub compute_cycles: u64,
    pub route_cycles: u64,
    pub host_cycles: u64,
    pub stream_cycles: u64,
    /// Fraction of PE slots busy during the compute phase.
    pub utilization: f64,
    /// Serialized wave count (folding).
    pub waves: u64,
    /// Weight footprint, bits (for residency accounting).
    pub weight_bits: u64,
}

impl LayerCost {
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.route_cycles + self.host_cycles + self.stream_cycles
    }
}

/// Whole-network cost.
#[derive(Debug, Clone)]
pub struct NetworkCost {
    pub network: String,
    pub layers: Vec<LayerCost>,
}

impl NetworkCost {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(LayerCost::total_cycles).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    pub fn seconds(&self, clock_ghz: f64) -> f64 {
        self.total_cycles() as f64 / (clock_ghz * 1e9)
    }

    /// Mean compute-phase utilization weighted by compute cycles.
    pub fn mean_utilization(&self) -> f64 {
        let num: f64 = self.layers.iter().map(|l| l.utilization * l.compute_cycles as f64).sum();
        let den: f64 = self.layers.iter().map(|l| l.compute_cycles as f64).sum();
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }
}

/// Cost a tiled mat-vec workload: `jobs` independent (rows × cols) tiles.
/// Returns (compute_cycles, utilization, waves).
fn tile_cost(model: &CostModel, jobs: u64, tile_rows: u64) -> (u64, f64, u64) {
    let waves = jobs.div_ceil(model.n_pes as u64);
    let compute = waves * tile_rows;
    let utilization = if waves == 0 { 0.0 } else { jobs as f64 / (waves * model.n_pes as u64) as f64 };
    (compute, utilization, waves)
}

/// Host cycles of a §4.4.3-II layer epilogue, mirroring the charges the
/// emitted program incurs in the simulator (`emit_fold_epilogue`): one
/// add per element per column tile beyond the first, plus the deferred
/// per-element ReLU and — for non-terminal layers — the output
/// quantizer, both of which move to the host when partial sums are
/// folded there. Zero for untiled layers (the PE datapath applies
/// bias/ReLU/quantize for free at the end of its adder tree).
fn case_ii_host(tw: usize, dout: u64, relu: bool, last: bool) -> u64 {
    if tw <= 1 {
        return 0;
    }
    let folds = (tw as u64 - 1) * dout;
    let act = if relu { dout } else { 0 };
    let quant = if last { 0 } else { dout };
    folds + act + quant
}

/// Streaming cycles when a layer's weights exceed residency.
fn stream_cost(model: &CostModel, weight_bits: u64) -> u64 {
    if weight_bits > model.residency_bits() {
        weight_bits.div_ceil(model.dma_bits_per_cycle)
    } else {
        0
    }
}

/// Map + cost one network on the model.
pub fn cost_network(model: &CostModel, net: &Network) -> Result<NetworkCost> {
    let shapes = net.shapes()?;
    let macs = net.macs()?;
    let mut layers = Vec::with_capacity(net.layers.len());
    for (i, l) in net.layers.iter().enumerate() {
        let (inp, outp) = (shapes[i], shapes[i + 1]);
        let m = macs[i];
        let d = decide_layer(model, &l.kind, inp, outp).with_context(|| format!("layer {}", l.name))?;
        let cost = match &l.kind {
            LayerKind::Fc { dout } => {
                let din = inp.flat();
                let nb = d.nb;
                let (bh, bw) = (dout / nb, din / nb);
                let (compute, util, waves) = tile_cost(model, d.jobs, d.tile_rows);
                // Routing: every tile's input slice delivered one value per
                // PE per cycle.
                let routed = d.jobs * bw.min(model.pe_w) as u64;
                let route = routed.div_ceil(model.n_pes as u64);
                // Host folds + deferred activation when the block is
                // split along its columns (§4.4.3-II).
                let host =
                    case_ii_host(d.tw, *dout as u64, l.relu, i + 1 == net.layers.len());
                let weight_bits = (nb * bh * bw) as u64 * model.bits as u64;
                LayerCost {
                    name: l.name.clone(),
                    case: d.case,
                    macs: m / nb as u64 * if d.case == MappingCase::FcStructured { 1 } else { nb as u64 },
                    compute_cycles: compute,
                    route_cycles: route,
                    host_cycles: host,
                    stream_cycles: stream_cost(model, weight_bits),
                    utilization: util,
                    waves,
                    weight_bits,
                }
            }
            LayerKind::Conv { cout, kh, kw, .. } => {
                let positions = (outp.h * outp.w) as u64;
                let g = d.groups;
                let (compute, util, waves) = tile_cost(model, d.jobs, d.tile_rows);
                // Input activations enter once per column-tile pass and are
                // reused across positions by the PE-local line buffer (the
                // paper's weight-stationary, activation-shuffling design) —
                // the routing network delivers the input volume, not the
                // im2col expansion.
                let route = (inp.flat() as u64 * (d.th * d.tw) as u64).div_ceil(model.n_pes as u64);
                let host =
                    case_ii_host(d.tw, positions * *cout as u64, l.relu, i + 1 == net.layers.len());
                let weight_bits = (cout * kh * kw * (inp.c / g)) as u64 * model.bits as u64;
                LayerCost {
                    name: l.name.clone(),
                    case: d.case,
                    macs: m,
                    compute_cycles: compute,
                    route_cycles: route,
                    host_cycles: host,
                    stream_cycles: stream_cost(model, weight_bits),
                    utilization: util,
                    waves,
                    weight_bits,
                }
            }
            LayerKind::MaxPool { window, .. } => {
                // Per output: window² loads + window²−1 max-combines,
                // the same per-element convention the simulator charges
                // (`sim::Apu::host_op`) — asserted equal in the
                // integration tests.
                let host = outp.flat() as u64 * (2 * (window * window) as u64 - 1);
                LayerCost {
                    name: l.name.clone(),
                    case: MappingCase::Host,
                    macs: 0,
                    compute_cycles: 0,
                    route_cycles: 0,
                    host_cycles: host,
                    stream_cycles: 0,
                    utilization: 0.0,
                    waves: 0,
                    weight_bits: 0,
                }
            }
            LayerKind::BatchNorm => LayerCost {
                name: l.name.clone(),
                case: MappingCase::Folded,
                macs: 0,
                compute_cycles: 0,
                route_cycles: 0,
                host_cycles: 0,
                stream_cycles: 0,
                utilization: 0.0,
                waves: 0,
                weight_bits: 0,
            },
            LayerKind::Attention { heads, dmodel, dk, seq } => {
                // Each head's projections are one dense block on one PE
                // (§4.4.4's PE_i → head_i mapping); the QK^T/AV batch of
                // seq-length mat-vecs rides the same blocks.
                let per_head_macs = m / *heads as u64;
                let (compute, util, waves) = tile_cost(model, d.jobs, d.tile_rows);
                let route = ((*seq * *dmodel) as u64).div_ceil(model.n_pes as u64);
                let weight_bits = (4 * dmodel * heads * dk) as u64 * model.bits as u64;
                LayerCost {
                    name: l.name.clone(),
                    case: d.case,
                    macs: per_head_macs * *heads as u64,
                    compute_cycles: compute,
                    route_cycles: route,
                    host_cycles: (*seq * *seq) as u64, // softmax on the host
                    stream_cycles: stream_cost(model, weight_bits),
                    utilization: util,
                    waves,
                    weight_bits,
                }
            }
        };
        layers.push(cost);
    }
    Ok(NetworkCost { network: net.name.clone(), layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn lenet_costs_are_sane() {
        let model = CostModel {
            n_pes: 10,
            pe_h: 400,
            pe_w: 400,
            bits: 4,
            clock_ghz: 1.0,
            fc_blocks: Some(10),
            group_conv: true,
            dma_bits_per_cycle: 64,
        };
        let c = cost_network(&model, &zoo::lenet_300_100()).unwrap();
        assert_eq!(c.layers.len(), 3);
        assert_eq!(c.layers[0].case, MappingCase::FcStructured);
        // fc1: 10 blocks of 30x80, one wave, 30 compute cycles
        assert_eq!(c.layers[0].compute_cycles, 30);
        assert_eq!(c.layers[0].waves, 1);
        assert!((c.layers[0].utilization - 1.0).abs() < 1e-9);
        // fc3 (100→10): dims don't divide nb=10 rows? 10/10=1, 100/10=10 → structured
        assert!(c.total_cycles() > 0);
    }

    #[test]
    fn conv_cases_classified() {
        let model = CostModel::paper_9pe();
        let vgg = zoo::vgg19(true);
        let c = cost_network(&model, &vgg).unwrap();
        let by_name = |n: &str| c.layers.iter().find(|l| l.name == n).unwrap();
        // conv1_1 (3→64, ungrouped): small kernel fits one PE
        assert_eq!(by_name("conv1_1").case, MappingCase::ConvSmall);
        // deep grouped convs are case III
        assert_eq!(by_name("conv5_4").case, MappingCase::ConvGroup);
        // pools on host
        assert_eq!(by_name("pool5").case, MappingCase::Host);
        // conv utilization high (the Fig. 13 claim)
        let conv_util: Vec<f64> = c
            .layers
            .iter()
            .filter(|l| matches!(l.case, MappingCase::ConvGroup | MappingCase::ConvSmall))
            .map(|l| l.utilization)
            .collect();
        let mean = conv_util.iter().sum::<f64>() / conv_util.len() as f64;
        assert!(mean > 0.9, "mean conv utilization {mean}");
    }

    #[test]
    fn dense_vs_grouped_vgg() {
        let mut dense_model = CostModel::paper_9pe();
        dense_model.group_conv = false;
        let grouped = cost_network(&CostModel::paper_9pe(), &zoo::vgg19(true)).unwrap();
        let dense = cost_network(&dense_model, &zoo::vgg19(false)).unwrap();
        // routing dominates both; grouping still wins clearly on the
        // compute phase and overall.
        assert!(
            dense.total_cycles() as f64 > grouped.total_cycles() as f64 * 1.2,
            "dense {} vs grouped {}",
            dense.total_cycles(),
            grouped.total_cycles()
        );
        let dc: u64 = dense.layers.iter().map(|l| l.compute_cycles).sum();
        let gc: u64 = grouped.layers.iter().map(|l| l.compute_cycles).sum();
        assert!(dc as f64 > gc as f64 * 1.5, "dense compute {dc} vs grouped {gc}");
    }

    #[test]
    fn oversized_fc_streams() {
        let model = CostModel::paper_9pe();
        // VGG FC6 structured at nb=10: 25088x4096/10 weights = 41 Mb > 9.4 Mb
        let c = cost_network(&model, &zoo::vgg19(true)).unwrap();
        let fc6 = c.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert!(fc6.stream_cycles > 0, "VGGFC6 must stream (the Fig. 15 dip)");
        assert!(fc6.waves > 1, "VGGFC6 must fold");
    }

    #[test]
    fn attention_maps_heads_to_pes() {
        let model = CostModel::paper_9pe();
        let c = cost_network(&model, &zoo::transformer_mha(8, 512, 64)).unwrap();
        assert_eq!(c.layers[0].case, MappingCase::Attention);
        assert_eq!(c.layers[0].waves, 1); // 8 heads ≤ 9 PEs
        assert!(c.layers[0].utilization > 0.8);
    }

    #[test]
    fn decide_layer_is_the_single_source_of_cases() {
        // cost_network is built on decide_layer; spot-check the decision
        // stands alone too (the emitter consumes it directly).
        let model = CostModel::paper_9pe();
        for net in [zoo::alexnet(), zoo::vgg19(true), zoo::resnet50(true), zoo::vgg_nano()] {
            let shapes = net.shapes().unwrap();
            let c = cost_network(&model, &net).unwrap();
            for (i, l) in net.layers.iter().enumerate() {
                let d = decide_layer(&model, &l.kind, shapes[i], shapes[i + 1]).unwrap();
                assert_eq!(d.case, c.layers[i].case, "{}: decision/cost disagree", l.name);
            }
        }
    }

    #[test]
    fn nano_model_makes_vgg_nano_fully_executable() {
        let model = CostModel::nano_4pe();
        let net = zoo::vgg_nano();
        let shapes = net.shapes().unwrap();
        for (i, l) in net.layers.iter().enumerate() {
            let d = decide_layer(&model, &l.kind, shapes[i], shapes[i + 1]).unwrap();
            if !matches!(d.case, MappingCase::Host | MappingCase::Folded) {
                assert!(d.fits_one_pe(), "{}: {:?} tiled {}x{}", l.name, d.case, d.th, d.tw);
            }
        }
    }

    #[test]
    fn resnet_utilization_high_on_convs() {
        let model = CostModel::paper_9pe();
        let c = cost_network(&model, &zoo::resnet50(true)).unwrap();
        let (util_sum, n) = c
            .layers
            .iter()
            .filter(|l| matches!(l.case, MappingCase::ConvGroup | MappingCase::ConvSmall | MappingCase::ConvLarge))
            .fold((0.0, 0usize), |(s, n), l| (s + l.utilization, n + 1));
        assert!(util_sum / n as f64 > 0.85);
    }
}
