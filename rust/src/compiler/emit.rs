//! Code emission: packed layers → executable APU program.

use std::borrow::Cow;

use anyhow::{bail, Result};

use crate::isa::{DataSegment, HostOpKind, Insn, Program};
use crate::pruning::{BlockStructure, PackedLayer};
use crate::sched::{build_demand, schedule_routes};
use crate::util::rng::Rng;

/// Split the network input stream into `n` chunk blocks (the first
/// layer's routing sources — the host streams input chunks onto the
/// crossbar wires). Also used for any host-produced buffer whose values
/// carry no PE ownership (post-pool/gather activations).
pub(crate) fn input_chunks(din: usize, n: usize) -> Vec<Vec<u32>> {
    let n = n.min(din).max(1);
    (0..n)
        .map(|g| {
            let lo = g * din / n;
            let hi = (g + 1) * din / n;
            (lo as u32..hi as u32).collect()
        })
        .collect()
}

/// Merge producer groups onto `n_pes` crossbar wires (folded layers own
/// more blocks than wires; wire = block mod n_pes). Borrows when the
/// groups already fit the wires — no copy on the common path.
pub(crate) fn merge_by_wire(groups: &[Vec<u32>], n_pes: usize) -> Cow<'_, [Vec<u32>]> {
    if groups.len() <= n_pes {
        return Cow::Borrowed(groups);
    }
    let mut merged = vec![Vec::new(); n_pes];
    for (g, grp) in groups.iter().enumerate() {
        merged[g % n_pes].extend_from_slice(grp);
    }
    Cow::Owned(merged)
}

/// Compile a stack of packed FC layers into an executable program.
///
/// Layers run back to back on the PE array; the ingress is quantized on
/// the host; each layer gets a static routing schedule. Layers with more
/// blocks than PEs are folded into waves (§4.4.3-II) sharing a `layer` id.
pub fn compile_packed_layers(
    name: &str,
    layers: &[PackedLayer],
    in_scale: f32,
    bits: u32,
    n_pes: usize,
) -> Result<Program> {
    if layers.is_empty() {
        bail!("no layers to compile");
    }
    for pair in layers.windows(2) {
        if pair[1].structure.din != pair[0].structure.dout {
            bail!(
                "layer dims mismatch: {} out vs {} in",
                pair[0].structure.dout,
                pair[1].structure.din
            );
        }
    }
    let mut p = Program {
        name: name.to_string(),
        din: layers[0].structure.din,
        dout: layers.last().unwrap().structure.dout,
        ..Default::default()
    };

    // Ingress quantizer on the host core.
    let q_seg = p.push_data(DataSegment::F32(vec![in_scale, bits as f32]));
    p.insns.push(Insn::HostOp { op: crate::isa::HostOpKind::Quantize, seg: q_seg });

    let mut producers: Cow<'_, [Vec<u32>]> = Cow::Owned(input_chunks(layers[0].structure.din, n_pes));
    for (li, layer) in layers.iter().enumerate() {
        // Imported bundles are packed to fit one PE by construction:
        // unbounded tile caps keep this path untiled.
        producers =
            emit_packed_fc(&mut p, li as u16, layer, &producers, li == 0, n_pes, usize::MAX, usize::MAX)?;
    }
    p.insns.push(Insn::Halt);
    if p.data.len() > u16::MAX as usize {
        bail!("{name}: {} data segments overflow the 16-bit segment table", p.data.len());
    }
    p.validate()?;
    Ok(p)
}

/// Emit one packed FC layer (all of its waves) into `p`.
///
/// `producers` are the previous layer's per-wire activation groups (or
/// input chunks for the first layer); the group *index* is the crossbar
/// wire its activations are broadcast on, which must equal the owning
/// PE's index modulo `n_pes` for the simulator's ownership check.
///
/// `pe_h`/`pe_w` are the PE block capacity: a block larger than one PE
/// is tiled into `th×tw` sub-blocks (§4.4.3-II). Row tiles split the
/// block's output rows across extra waves; column tiles produce partial
/// sums that land in named host buffers (`Scatter { buf: t, .. }`) and
/// are folded by runtime `FoldAdd` ops — bias rides column tile 0 and
/// ReLU/output quantization run on the host after the last fold, so
/// both apply exactly once. Pass caps at least as large as the block
/// (e.g. `usize::MAX`) for the untiled fast path.
///
/// Returns this layer's producer groups for the next layer — borrowed
/// straight from the layer's block structure on the untiled path (no
/// per-layer copy). Shared by [`compile_packed_layers`] and the graph
/// pipeline (`compiler::pipeline`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn emit_packed_fc<'a>(
    p: &mut Program,
    layer_id: u16,
    layer: &'a PackedLayer,
    producers: &[Vec<u32>],
    from_input: bool,
    n_pes: usize,
    pe_h: usize,
    pe_w: usize,
) -> Result<Cow<'a, [Vec<u32>]>> {
    let s = &layer.structure;
    let producers = merge_by_wire(producers, n_pes);
    let (bh, bw) = (s.bh(), s.bw());
    let (th, tw) = (bh.div_ceil(pe_h), bw.div_ceil(pe_w));
    if tw > 1 {
        // The host epilogue applies one quantizer scale to the whole
        // stream, so a column-tiled lowering must be uniform.
        if let Some(&os) = layer.out_scale.iter().find(|&&os| os != layer.out_scale[0]) {
            bail!("column-tiled FC needs a uniform out_scale ({os} vs {})", layer.out_scale[0]);
        }
    }
    let blocks: Vec<usize> = (0..s.nb).collect();
    for r in 0..th {
        let r0 = r * pe_h.min(bh);
        let rows = pe_h.min(bh - r0);
        for t in 0..tw {
            let c0 = t * pe_w.min(bw);
            let cols = pe_w.min(bw - c0);
            // PE-side bias/ReLU/quantizer only when no fold follows:
            // with column tiles they move to the host epilogue.
            let in_pe_act = tw == 1;
            // Fold each tile's blocks into waves of at most n_pes.
            for wave in blocks.chunks(n_pes) {
                let wave_nb = wave.len();
                p.insns.push(Insn::ConfigLayer {
                    layer: layer_id,
                    nb: wave_nb as u16,
                    bh: rows as u16,
                    bw: cols as u16,
                    bits: layer.bits as u8,
                    relu: layer.relu && in_pe_act,
                });
                for (pe, &g) in wave.iter().enumerate() {
                    let codes = &layer.codes[g];
                    let mut tile = Vec::with_capacity(rows * cols);
                    for i in 0..rows {
                        let base = (r0 + i) * bw + c0;
                        tile.extend_from_slice(&codes[base..base + cols]);
                    }
                    let bias: Vec<f32> = if t == 0 {
                        layer.bias[g][r0..r0 + rows].to_vec()
                    } else {
                        vec![0.0; rows]
                    };
                    let out_scale = if in_pe_act { layer.out_scale[g] } else { 0.0 };
                    let w_seg = p.push_data(DataSegment::I8(tile));
                    let b_seg = p.push_data(DataSegment::F32(bias));
                    let s_seg = p.push_data(DataSegment::F32(vec![layer.w_scale[g], out_scale]));
                    p.insns.push(Insn::LoadWeights { pe: pe as u16, seg: w_seg });
                    p.insns.push(Insn::LoadBias { pe: pe as u16, seg: b_seg });
                    p.insns.push(Insn::SetScales { pe: pe as u16, seg: s_seg });
                }
                // Static routing schedule for this wave's column slice.
                let consumers: Vec<Vec<u32>> =
                    wave.iter().map(|&g| s.col_groups[g][c0..c0 + cols].to_vec()).collect();
                let demand = build_demand(&producers, &consumers)?;
                let sched = schedule_routes(&demand)?;
                sched.verify(&demand)?;
                let r_seg = p.push_data(DataSegment::Routes(sched.assignments));
                p.insns.push(Insn::Route { seg: r_seg, from_input });
                p.insns.push(Insn::Compute { rows: rows as u16 });
                // Scatter segment: [dout, wave row indices...]
                let mut scat = Vec::with_capacity(1 + wave_nb * rows);
                scat.push(s.dout as u32);
                for &g in wave {
                    scat.extend_from_slice(&s.row_groups[g][r0..r0 + rows]);
                }
                let sc_seg = p.push_data(DataSegment::U32(scat));
                p.insns.push(Insn::Scatter { seg: sc_seg, buf: t as u16 });
            }
        }
    }
    if tw > 1 {
        emit_fold_epilogue(p, tw, layer.relu, layer.out_scale[0], layer.bits);
        // Folded outputs are host-owned: chunk them across wires.
        return Ok(Cow::Owned(input_chunks(s.dout, n_pes)));
    }
    Ok(Cow::Borrowed(s.row_groups.as_slice()))
}

/// Emit the §4.4.3-II layer epilogue: fold each named partial buffer
/// into the committed stream (runtime `FoldAdd`, one per column tile
/// beyond the first), then apply ReLU and the output quantizer on the
/// host — exactly once, after the last fold. Shared by the tiled FC and
/// tiled conv emitters.
pub(crate) fn emit_fold_epilogue(p: &mut Program, tw: usize, relu: bool, out_scale: f32, bits: u32) {
    for t in 1..tw {
        let seg = p.push_data(DataSegment::F32(vec![t as f32]));
        p.insns.push(Insn::HostOp { op: HostOpKind::FoldAdd, seg });
    }
    if relu {
        let seg = p.push_data(DataSegment::F32(Vec::new()));
        p.insns.push(Insn::HostOp { op: HostOpKind::Relu, seg });
    }
    if out_scale > 0.0 {
        let seg = p.push_data(DataSegment::F32(vec![out_scale, bits as f32]));
        p.insns.push(Insn::HostOp { op: HostOpKind::Quantize, seg });
    }
}

/// Synthesize a random packed FC network (figure benches and property
/// tests): `dims = [din, h1, ..., dout]`, `nb` blocks per layer.
pub fn synthetic_packed_network(dims: &[usize], nb: usize, bits: u32, seed: u64) -> Result<Vec<PackedLayer>> {
    if dims.len() < 2 {
        bail!("need at least one layer");
    }
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for (li, pair) in dims.windows(2).enumerate() {
        let (din, dout) = (pair[0], pair[1]);
        let s = BlockStructure::random(dout, din, nb, &mut rng)?;
        let w: Vec<f32> = (0..dout * din).map(|_| rng.normal() * (2.0 / din as f32).sqrt()).collect();
        let b: Vec<f32> = (0..dout).map(|_| rng.normal() * 0.05).collect();
        let out_scale: Vec<f32> = (0..nb).map(|_| 0.1 + rng.f64() as f32 * 0.4).collect();
        let relu = li + 1 < dims.len() - 1 || dims.len() == 2;
        layers.push(PackedLayer::quantize_from(s, bits, &w, &b, out_scale, relu)?);
    }
    Ok(layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiles_and_validates() {
        let layers = synthetic_packed_network(&[16, 20, 12], 4, 4, 7).unwrap();
        let p = compile_packed_layers("t", &layers, 0.1, 4, 4).unwrap();
        assert_eq!(p.din, 16);
        assert_eq!(p.dout, 12);
        // one wave per layer: 2 ConfigLayers
        let cfgs = p.insns.iter().filter(|i| matches!(i, Insn::ConfigLayer { .. })).count();
        assert_eq!(cfgs, 2);
    }

    #[test]
    fn folding_emits_waves() {
        let layers = synthetic_packed_network(&[16, 20], 4, 4, 8).unwrap();
        let p = compile_packed_layers("t", &layers, 0.1, 4, 2).unwrap();
        let cfgs: Vec<_> = p
            .insns
            .iter()
            .filter_map(|i| match i {
                Insn::ConfigLayer { layer, nb, .. } => Some((*layer, *nb)),
                _ => None,
            })
            .collect();
        assert_eq!(cfgs, vec![(0, 2), (0, 2)]); // 4 blocks → 2 waves of 2
    }

    #[test]
    fn rejects_dim_mismatch() {
        let l1 = synthetic_packed_network(&[16, 20], 4, 4, 9).unwrap();
        let l2 = synthetic_packed_network(&[24, 12], 4, 4, 10).unwrap();
        let stack: Vec<_> = l1.into_iter().chain(l2).collect();
        assert!(compile_packed_layers("t", &stack, 0.1, 4, 4).is_err());
    }

    #[test]
    fn input_chunks_partition() {
        let ch = input_chunks(17, 4);
        let all: Vec<u32> = ch.iter().flatten().copied().collect();
        assert_eq!(all, (0..17).collect::<Vec<u32>>());
    }

    #[test]
    fn merge_by_wire_unions() {
        let groups = vec![vec![0], vec![1], vec![2], vec![3], vec![4]];
        let merged = merge_by_wire(&groups, 2);
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0], vec![0, 2, 4]);
        assert_eq!(merged[1], vec![1, 3]);
    }

    #[test]
    fn disassembly_is_stable() {
        let layers = synthetic_packed_network(&[8, 8], 2, 4, 11).unwrap();
        let p = compile_packed_layers("t", &layers, 0.1, 4, 2).unwrap();
        let asm = p.disassemble();
        assert!(asm.contains("cfg.layer") && asm.contains("route") && asm.ends_with("halt\n"));
    }
}
