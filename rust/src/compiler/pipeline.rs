//! Pass-based compiler pipeline: any [`nn::Network`](crate::nn::Network)
//! + machine model → executable [`isa::Program`](crate::isa::Program).
//!
//! The passes run in a fixed order:
//!
//! 1. **Graph normalization** (`nn::passes::normalize`) — fold `BatchNorm`
//!    layers into the preceding conv/FC and fuse their trailing-ReLU flags
//!    (paper §4.4.3 "Batch Normalization").
//! 2. **Weight materialization + numeric fold** — [`NetworkWeights`]
//!    carries per-layer dense weights (synthesized deterministically for
//!    shape-library networks); the batch-norm fold is applied numerically
//!    (`W' = s·W`, `b' = s·b + t`).
//! 3. **Mapping** — one [`MappingDecision`] per layer from
//!    [`decide_layer`], the *same* decision the analytic cost model uses,
//!    so the emitted program and the cycle prediction can never disagree
//!    on a layer's §4.4.3 case.
//! 4. **Lowering + compression** — FC layers get structured pruning +
//!    INT-k quantization (`pruning::{BlockStructure, PackedLayer}`);
//!    convolutions lower to per-position mat-vecs over an im2col-style
//!    unrolled kernel, one group per PE (case I when `groups == 1`, case
//!    III group conv otherwise), with the host `Gather` op materializing
//!    the zero-padded input plane; pooling lowers to a `HostOp`.
//! 5. **Emission** — static routing schedules (`sched`), wave folding
//!    when blocks/positions exceed the PE count, and the final `Insn`
//!    stream the cycle-accurate simulator executes.
//!
//! Case II mappings (`ConvLarge`, grouped convs whose per-group kernel
//! exceeds one PE, and FC blocks tiled across PEs) are fully
//! executable: a block/kernel larger than one PE is tiled into `th×tw`
//! sub-blocks, each tile runs as its own ConfigLayer/Route/Compute
//! waves, column-tile partial sums land in named host buffers
//! (`Scatter { buf, .. }`), and runtime-operand `FoldAdd` host ops fold
//! them into the stream — bias applied exactly once (column tile 0),
//! ReLU and the output quantizer applied on the host only after the
//! final fold. Attention (§4.4.4) remains analytic-only —
//! [`compile_network`] reports it as non-executable while [`analyze`]
//! still costs it.
//!
//! **Wave-count caveat:** the emitter schedules each tile's jobs in its
//! own waves, while the analytic model packs all of a layer's jobs into
//! one wave sequence and charges every job a full `tile_rows` of
//! compute; the two wave (and compute-cycle) counts agree exactly
//! whenever each tile's job count divides the PE count evenly
//! (`positions % n_pes == 0` for convs, `nb % n_pes == 0` for FCs) and
//! row tiles are not ragged (`bh % pe_h == 0` whenever `th > 1` — a
//! ragged last row tile computes fewer rows than the analytic charge).
//! That is the geometry the cross-validation tests and the zoo's tiled
//! reference network (`zoo::alexnet_nano`) use.
//!
//! **Route-cycle caveat:** the analytic model charges conv routing at
//! line-buffer reuse (the input volume enters once per column-tile pass,
//! §3.1.2), while the emitted per-position schedules deliver the full
//! im2col expansion — simulated route cycles for convs exceed the
//! analytic figure by roughly `kh·kw`. Mapping cases, compute cycles,
//! and MAC counts are the cross-validated quantities
//! (`rust/tests/integration_pipeline.rs`); closing the route gap needs a
//! PE-local line buffer in the simulator (ROADMAP follow-up).

use anyhow::{bail, Context, Result};

use crate::compiler::cost::{
    cost_network, decide_layer, CostModel, MappingCase, MappingDecision, NetworkCost,
};
use crate::compiler::emit::{emit_fold_epilogue, emit_packed_fc, input_chunks};
use crate::isa::{DataSegment, HostOpKind, Insn, Program};
use crate::nn::graph::{LayerKind, Network};
use crate::nn::passes::{normalize, LayerFate, Normalized};
use crate::obs::trace::{Tracer, PID_COMPILER};
use crate::pruning::{BlockStructure, PackedLayer, Quantizer};
use crate::sched::{build_demand, schedule_routes};
use crate::sim::host_maxpool;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Emission budget: total routed activation values across the program. A
/// full-resolution VGG-19 would emit tens of millions of static route
/// assignments; past this bound the pipeline refuses emission and points
/// at [`analyze`] instead.
const MAX_ROUTE_ITEMS: u64 = 20_000_000;

/// Pipeline knobs.
#[derive(Debug, Clone)]
pub struct PipelineOptions {
    /// Seed for synthetic weights and pruning structures.
    pub seed: u64,
    /// Ingress quantizer scale (host `Quantize` op at program start).
    pub in_scale: f32,
    /// When set, each pass (`normalize`, `decide_layer`, `compress`,
    /// `emit`) records a span for Chrome trace-event export.
    pub tracer: Option<Tracer>,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions { seed: 7, in_scale: 0.5, tracer: None }
    }
}

// ---------------------------------------------------------------------------
// Weights (pass 2)
// ---------------------------------------------------------------------------

/// Dense per-layer parameters, aligned with a network's layer list.
#[derive(Debug, Clone)]
pub enum LayerParams {
    /// Row-major `dout × din` weights + bias.
    Fc { w: Vec<f32>, b: Vec<f32> },
    /// Row-major `cout × (kh·kw·cin/groups)` unrolled filters + bias;
    /// row `r` belongs to group `r / (cout/groups)`, columns iterate
    /// `(ky, kx, ci-within-group)`.
    Conv { w: Vec<f32>, b: Vec<f32> },
    /// Per-channel affine: `y = scale·x + shift`.
    BatchNorm { scale: Vec<f32>, shift: Vec<f32> },
    /// Parameter-free layer (pooling, attention placeholder).
    None,
}

/// A network's dense weights (pre-compression).
#[derive(Debug, Clone)]
pub struct NetworkWeights {
    pub layers: Vec<LayerParams>,
}

impl NetworkWeights {
    /// Deterministic He-style synthetic weights for a shape-library
    /// network (the zoo carries geometry, not trained values).
    pub fn synthetic(net: &Network, seed: u64) -> Result<NetworkWeights> {
        let shapes = net.shapes()?;
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(net.layers.len());
        for (i, l) in net.layers.iter().enumerate() {
            let inp = shapes[i];
            let params = match &l.kind {
                LayerKind::Fc { dout } => {
                    let din = inp.flat();
                    let scale = (2.0 / din as f32).sqrt();
                    let w: Vec<f32> = (0..dout * din).map(|_| rng.normal() * scale).collect();
                    let b: Vec<f32> = (0..*dout).map(|_| rng.normal() * 0.05).collect();
                    LayerParams::Fc { w, b }
                }
                LayerKind::Conv { cout, kh, kw, groups, .. } => {
                    let kvol = kh * kw * (inp.c / groups);
                    let scale = (2.0 / kvol as f32).sqrt();
                    let w: Vec<f32> = (0..cout * kvol).map(|_| rng.normal() * scale).collect();
                    let b: Vec<f32> = (0..*cout).map(|_| rng.normal() * 0.05).collect();
                    LayerParams::Conv { w, b }
                }
                LayerKind::BatchNorm => {
                    let c = inp.c;
                    let scale: Vec<f32> = (0..c).map(|_| rng.uniform(0.5, 1.5)).collect();
                    let shift: Vec<f32> = (0..c).map(|_| rng.normal() * 0.1).collect();
                    LayerParams::BatchNorm { scale, shift }
                }
                LayerKind::MaxPool { .. } | LayerKind::Attention { .. } => LayerParams::None,
            };
            layers.push(params);
        }
        Ok(NetworkWeights { layers })
    }

    /// Apply the numeric batch-norm fold matching a [`normalize`] result:
    /// `y = s·(Wx + b) + t ⇒ W' = s·W, b' = s·b + t` per output unit.
    /// Returns weights aligned with the *normalized* layer list.
    pub fn fold(mut self, norm: &Normalized) -> Result<NetworkWeights> {
        if self.layers.len() != norm.fates.len() {
            bail!("weights cover {} layers but network has {}", self.layers.len(), norm.fates.len());
        }
        let mut out: Vec<LayerParams> = Vec::with_capacity(norm.net.layers.len());
        for (i, fate) in norm.fates.iter().enumerate() {
            match fate {
                LayerFate::Kept(_) => out.push(std::mem::replace(&mut self.layers[i], LayerParams::None)),
                LayerFate::FoldedInto(j) => {
                    let LayerParams::BatchNorm { scale, shift } = &self.layers[i] else {
                        bail!("layer {i} marked folded but carries no batch-norm parameters");
                    };
                    let target = out
                        .get_mut(*j)
                        .with_context(|| format!("fold target {j} not yet lowered"))?;
                    let (w, b) = match target {
                        LayerParams::Fc { w, b } | LayerParams::Conv { w, b } => (w, b),
                        _ => bail!("fold target {j} is not a conv/FC layer"),
                    };
                    if b.len() != scale.len() {
                        bail!("batch-norm width {} != producer width {}", scale.len(), b.len());
                    }
                    let cols = w.len() / b.len();
                    for (r, (s, t)) in scale.iter().zip(shift).enumerate() {
                        for v in &mut w[r * cols..(r + 1) * cols] {
                            *v *= s;
                        }
                        b[r] = b[r] * s + t;
                    }
                }
            }
        }
        Ok(NetworkWeights { layers: out })
    }
}

/// Full-precision float reference for a network + weights (no
/// quantization) — the oracle for the batch-norm fold.
pub fn float_forward(net: &Network, weights: &NetworkWeights, x: &[f32]) -> Result<Vec<f32>> {
    let shapes = net.shapes()?;
    if weights.layers.len() != net.layers.len() {
        bail!("weights cover {} layers but network has {}", weights.layers.len(), net.layers.len());
    }
    if x.len() != shapes[0].flat() {
        bail!("input len {} != network din {}", x.len(), shapes[0].flat());
    }
    let mut acts = x.to_vec();
    for (i, l) in net.layers.iter().enumerate() {
        let (inp, outp) = (shapes[i], shapes[i + 1]);
        acts = match (&l.kind, &weights.layers[i]) {
            (LayerKind::Fc { dout }, LayerParams::Fc { w, b }) => {
                let din = inp.flat();
                let mut out = vec![0f32; *dout];
                for (r, o) in out.iter_mut().enumerate() {
                    let mut acc = 0f64;
                    for (c, &a) in acts.iter().enumerate() {
                        acc += w[r * din + c] as f64 * a as f64;
                    }
                    let v = acc as f32 + b[r];
                    *o = if l.relu { v.max(0.0) } else { v };
                }
                out
            }
            (LayerKind::Conv { cout, kh, kw, stride, groups, padding }, LayerParams::Conv { w, b }) => {
                let (h, wdt, c) = (inp.h, inp.w, inp.c);
                let cin_g = c / groups;
                let kvol = kh * kw * cin_g;
                let bh = cout / groups;
                let mut out = vec![0f32; outp.h * outp.w * cout];
                for oy in 0..outp.h {
                    for ox in 0..outp.w {
                        for oc in 0..*cout {
                            let q = oc / bh;
                            let mut acc = 0f64;
                            for ky in 0..*kh {
                                for kx in 0..*kw {
                                    let iy = (oy * stride + ky) as isize - *padding as isize;
                                    let ix = (ox * stride + kx) as isize - *padding as isize;
                                    if iy < 0 || ix < 0 || iy >= h as isize || ix >= wdt as isize {
                                        continue;
                                    }
                                    for ci in 0..cin_g {
                                        let a = acts[((iy as usize) * wdt + ix as usize) * c + q * cin_g + ci];
                                        let wv = w[oc * kvol + (ky * kw + kx) * cin_g + ci];
                                        acc += wv as f64 * a as f64;
                                    }
                                }
                            }
                            let v = acc as f32 + b[oc];
                            out[(oy * outp.w + ox) * cout + oc] = if l.relu { v.max(0.0) } else { v };
                        }
                    }
                }
                out
            }
            (LayerKind::MaxPool { window, stride }, _) => {
                host_maxpool(&acts, inp.h, inp.w, inp.c, *window, *stride)?
            }
            (LayerKind::BatchNorm, LayerParams::BatchNorm { scale, shift }) => {
                let c = inp.c;
                let mut out = acts.clone();
                for (idx, v) in out.iter_mut().enumerate() {
                    let ch = idx % c;
                    let y = *v * scale[ch] + shift[ch];
                    *v = if l.relu { y.max(0.0) } else { y };
                }
                out
            }
            (LayerKind::Attention { .. }, _) => bail!("{}: attention has no float reference", l.name),
            _ => bail!("{}: weights do not match layer kind", l.name),
        };
    }
    Ok(acts)
}

// ---------------------------------------------------------------------------
// Lowered layers (pass 4)
// ---------------------------------------------------------------------------

/// A convolution lowered for the PE array: per-group INT-k codes over the
/// im2col-unrolled kernel, executed as one mat-vec per output position.
#[derive(Debug, Clone)]
pub struct ConvLayer {
    pub name: String,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub padding: usize,
    /// Mapped group count (§4.4.3 case III when > 1).
    pub groups: usize,
    pub oh: usize,
    pub ow: usize,
    /// `codes[g]` — row-major `(cout/groups) × kvol` INT-k codes.
    pub codes: Vec<Vec<i8>>,
    pub w_scale: Vec<f32>,
    pub bias: Vec<Vec<f32>>,
    /// Per-group output quantizer scale; `0.0` bypasses (logit head).
    /// Uniform across groups whenever the layer is column-tiled (the
    /// host epilogue applies one scale to the whole stream).
    pub out_scale: Vec<f32>,
    pub relu: bool,
    pub bits: u32,
    /// PE block capacity the layer was mapped against: a group block
    /// larger than `tile_h × tile_w` is tiled (§4.4.3-II).
    pub tile_h: usize,
    pub tile_w: usize,
}

impl ConvLayer {
    pub fn kvol(&self) -> usize {
        self.kh * self.kw * (self.in_c / self.groups)
    }

    /// Rows per group block (= output channels each PE computes).
    pub fn bh(&self) -> usize {
        self.cout / self.groups
    }

    /// Row tiles per group block (§4.4.3-II when > 1).
    pub fn th(&self) -> usize {
        self.bh().div_ceil(self.tile_h)
    }

    /// Column tiles per group block — each beyond the first produces a
    /// partial-sum buffer the host folds.
    pub fn tw(&self) -> usize {
        self.kvol().div_ceil(self.tile_w)
    }

    /// Functional reference for one input plane (channel-last `h×w×c`),
    /// mirroring the PE datapath exactly: integer codes × grid inputs in
    /// an f64 tree *per column tile*, bias on column tile 0, f32 folds
    /// in tile order, then ReLU and the end-of-tree quantizer — the same
    /// arithmetic whether the fold happens inside one PE (`tw == 1`) or
    /// across the host's partial-sum buffers (§4.4.3-II).
    pub fn forward(&self, acts: &[f32]) -> Result<Vec<f32>> {
        if acts.len() != self.in_h * self.in_w * self.in_c {
            bail!("{}: input len {} != {}x{}x{}", self.name, acts.len(), self.in_h, self.in_w, self.in_c);
        }
        let padded = self.padded(acts);
        let (pw, c) = (self.in_w + 2 * self.padding, self.in_c);
        let (bh, kvol, cin_g) = (self.bh(), self.kvol(), self.in_c / self.groups);
        let tw = self.tw();
        let mut out = vec![0f32; self.oh * self.ow * self.cout];
        let mut latch = vec![0f32; kvol];
        for pos in 0..self.oh * self.ow {
            let (oy, ox) = (pos / self.ow, pos % self.ow);
            for q in 0..self.groups {
                // latch fill in route-slot order: (ky, kx, ci-within-group)
                let mut slot = 0;
                for ky in 0..self.kh {
                    for kx in 0..self.kw {
                        let (y, x) = (oy * self.stride + ky, ox * self.stride + kx);
                        for ci in 0..cin_g {
                            latch[slot] = padded[(y * pw + x) * c + q * cin_g + ci];
                            slot += 1;
                        }
                    }
                }
                let oq = (self.out_scale[q] > 0.0).then(|| Quantizer::new(self.bits, self.out_scale[q]));
                for i in 0..bh {
                    let row = &self.codes[q][i * kvol..(i + 1) * kvol];
                    let mut o = 0f32;
                    for t in 0..tw {
                        let c0 = t * self.tile_w.min(kvol);
                        let c1 = kvol.min(c0 + self.tile_w);
                        let acc: f64 = row[c0..c1]
                            .iter()
                            .zip(&latch[c0..c1])
                            .map(|(&cd, &a)| cd as f64 * a as f64)
                            .sum();
                        let part =
                            acc as f32 * self.w_scale[q] + if t == 0 { self.bias[q][i] } else { 0.0 };
                        o = if t == 0 { part } else { o + part };
                    }
                    if self.relu {
                        o = o.max(0.0);
                    }
                    if let Some(qz) = &oq {
                        o = qz.fake(o);
                    }
                    out[pos * self.cout + q * bh + i] = o;
                }
            }
        }
        Ok(out)
    }

    /// The zero-padded input plane the emitted host `Gather` materializes.
    fn padded(&self, acts: &[f32]) -> Vec<f32> {
        let (h, w, c, p) = (self.in_h, self.in_w, self.in_c, self.padding);
        if p == 0 {
            return acts.to_vec();
        }
        let (ph, pw) = (h + 2 * p, w + 2 * p);
        let mut out = vec![0f32; ph * pw * c];
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    out[((y + p) * pw + (x + p)) * c + ch] = acts[(y * w + x) * c + ch];
                }
            }
        }
        out
    }
}

/// One lowered layer, ready for emission.
#[derive(Debug, Clone)]
pub enum Lowered {
    /// Structured-pruned (or nb=1 dense) FC on the PE array; blocks
    /// larger than one PE tile across waves + host folds (§4.4.3-II).
    Fc(PackedLayer),
    /// Conv as per-position mat-vecs (cases I/II/III).
    Conv(ConvLayer),
    /// Max-pool on the host core.
    Pool { h: usize, w: usize, c: usize, window: usize, stride: usize },
}

/// Functional reference for a column-tiled FC block (§4.4.3-II),
/// mirroring the emitted program exactly: an f64 tree per `tile_w`-wide
/// column tile → f32 partial (PE), bias on tile 0 only, f32 folds in
/// tile order (host `FoldAdd`), then ReLU and the *uniform* output
/// quantizer after the last fold (host epilogue).
fn tiled_fc_forward(layer: &PackedLayer, tile_w: usize, a: &[f32]) -> Result<Vec<f32>> {
    let s = &layer.structure;
    if a.len() != s.din {
        bail!("input len {} != din {}", a.len(), s.din);
    }
    let (bh, bw) = (s.bh(), s.bw());
    let tw = bw.div_ceil(tile_w);
    let oq = (layer.out_scale[0] > 0.0).then(|| Quantizer::new(layer.bits, layer.out_scale[0]));
    let mut out = vec![0f32; s.dout];
    for g in 0..s.nb {
        for i in 0..bh {
            let row = &layer.codes[g][i * bw..(i + 1) * bw];
            let mut o = 0f32;
            for t in 0..tw {
                let c0 = t * tile_w.min(bw);
                let c1 = bw.min(c0 + tile_w);
                let mut acc = 0f64;
                for j in c0..c1 {
                    acc += row[j] as f64 * a[s.col_groups[g][j] as usize] as f64;
                }
                let part = acc as f32 * layer.w_scale[g] + if t == 0 { layer.bias[g][i] } else { 0.0 };
                o = if t == 0 { part } else { o + part };
            }
            if layer.relu {
                o = o.max(0.0);
            }
            out[s.row_groups[g][i] as usize] = match &oq {
                Some(q) => q.fake(o),
                None => o,
            };
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Analysis (passes 1 + 3, no emission)
// ---------------------------------------------------------------------------

/// Mapping + cost for a network without emitting a program — works for
/// every layer kind, including the analytic-only attention mapping.
#[derive(Debug, Clone)]
pub struct NetworkAnalysis {
    pub normalized: Normalized,
    pub decisions: Vec<MappingDecision>,
    pub cost: NetworkCost,
}

impl NetworkAnalysis {
    /// Per-layer mapping/cost table (the `apu compile` report).
    pub fn table(&self) -> String {
        mapping_table(&self.cost, &self.decisions)
    }
}

/// Run the graph passes and the shared mapping decision, then cost the
/// normalized network analytically.
pub fn analyze(net: &Network, model: &CostModel) -> Result<NetworkAnalysis> {
    let normalized = normalize(net)?;
    let shapes = normalized.net.shapes()?;
    let mut decisions = Vec::with_capacity(normalized.net.layers.len());
    for (i, l) in normalized.net.layers.iter().enumerate() {
        let d = decide_layer(model, &l.kind, shapes[i], shapes[i + 1])
            .with_context(|| format!("layer {}", l.name))?;
        decisions.push(d);
    }
    let cost = cost_network(model, &normalized.net)?;
    Ok(NetworkAnalysis { normalized, decisions, cost })
}

/// Render the per-layer mapping/cost table.
pub fn mapping_table(cost: &NetworkCost, decisions: &[MappingDecision]) -> String {
    let mut s = format!(
        "{:<14} {:<13} {:>5} {:>12} {:>10} {:>10} {:>10} {:>10} {:>6} {:>6}\n",
        "layer", "case", "nb/g", "macs", "compute", "route", "host", "stream", "util%", "waves"
    );
    for (l, d) in cost.layers.iter().zip(decisions) {
        let nbg = if l.case == MappingCase::FcStructured || l.case == MappingCase::FcDense {
            d.nb
        } else {
            d.groups
        };
        s.push_str(&format!(
            "{:<14} {:<13} {:>5} {:>12} {:>10} {:>10} {:>10} {:>10} {:>6.1} {:>6}\n",
            l.name,
            format!("{:?}", l.case),
            nbg,
            l.macs,
            l.compute_cycles,
            l.route_cycles,
            l.host_cycles,
            l.stream_cycles,
            l.utilization * 100.0,
            l.waves
        ));
    }
    s.push_str(&format!(
        "{:<14} {:<13} {:>5} {:>12} {:>10}   total cycles, mean util {:.1}%\n",
        "TOTAL",
        "",
        "",
        cost.total_macs(),
        cost.total_cycles(),
        cost.mean_utilization() * 100.0
    ));
    s
}

// ---------------------------------------------------------------------------
// Full compilation
// ---------------------------------------------------------------------------

/// A network compiled end to end: the executable program, the lowered
/// layers (for the functional reference), and the analytic view built
/// from the *same* mapping decisions.
#[derive(Debug, Clone)]
pub struct CompiledNetwork {
    pub name: String,
    pub model: CostModel,
    pub program: Program,
    pub lowered: Vec<Lowered>,
    /// One decision per normalized layer (parallel to `cost.layers`).
    pub decisions: Vec<MappingDecision>,
    pub cost: NetworkCost,
    pub in_scale: f32,
    pub bits: u32,
}

impl CompiledNetwork {
    /// Functional reference the cycle-accurate simulator must reproduce
    /// bit-for-bit (ingress quantize → lowered layers in order).
    pub fn reference_forward(&self, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.program.din {
            bail!("input len {} != program din {}", x.len(), self.program.din);
        }
        let q = Quantizer::new(self.bits, self.in_scale);
        let mut acts: Vec<f32> = x.iter().map(|&v| q.fake(v)).collect();
        for low in &self.lowered {
            acts = match low {
                Lowered::Fc(p) => {
                    if p.structure.bw().div_ceil(self.model.pe_w) == 1 {
                        p.forward(&acts)?
                    } else {
                        tiled_fc_forward(p, self.model.pe_w, &acts)?
                    }
                }
                Lowered::Conv(cv) => cv.forward(&acts)?,
                Lowered::Pool { h, w, c, window, stride } => {
                    host_maxpool(&acts, *h, *w, *c, *window, *stride)?
                }
            };
        }
        Ok(acts)
    }

    /// Per-layer mapping/cost table.
    pub fn table(&self) -> String {
        mapping_table(&self.cost, &self.decisions)
    }
}

/// Run the full pipeline: normalize → weights+fold → map → lower →
/// emit. Errors (rather than silently degrading) when a layer's mapping
/// is analytic-only (attention) or the program would exceed the
/// emission budget.
pub fn compile_network(net: &Network, model: &CostModel, opts: &PipelineOptions) -> Result<CompiledNetwork> {
    if opts.in_scale <= 0.0 {
        bail!("in_scale must be positive, got {}", opts.in_scale);
    }
    let tr = opts.tracer.as_ref();
    let pass_span = |name: &str, t0: Option<f64>, args: Vec<(String, Json)>| {
        if let (Some(t), Some(t0)) = (tr, t0) {
            t.end_span(name, "compiler", PID_COMPILER, 0, t0, args);
        }
    };
    // Pass 1: graph normalization.
    let t0 = tr.map(|t| t.begin());
    let norm = normalize(net)?;
    pass_span(
        "normalize",
        t0,
        vec![
            ("layers_in".into(), Json::Int(net.layers.len() as i64)),
            ("layers_out".into(), Json::Int(norm.net.layers.len() as i64)),
        ],
    );
    // Pass 3 pre-flight (before materializing weights — an ImageNet-scale
    // network carries hundreds of MB of synthetic parameters): every
    // layer must be executable and the route schedule affordable.
    let t0 = tr.map(|t| t.begin());
    let shapes = norm.net.shapes()?;
    let mut decisions = Vec::with_capacity(norm.net.layers.len());
    let mut items = 0u64;
    for (i, l) in norm.net.layers.iter().enumerate() {
        let (inp, outp) = (shapes[i], shapes[i + 1]);
        let d = decide_layer(model, &l.kind, inp, outp).with_context(|| format!("layer {}", l.name))?;
        ensure_executable(l, &d)?;
        // Each row tile re-latches the layer's input slice, so tiled
        // layers route th× the untiled volume.
        items += match &l.kind {
            LayerKind::Fc { .. } => (inp.flat() * d.th) as u64,
            LayerKind::Conv { kh, kw, .. } => {
                (outp.h * outp.w * d.groups * d.th) as u64 * (kh * kw * (inp.c / d.groups)) as u64
            }
            _ => 0,
        };
        decisions.push(d);
    }
    if items > MAX_ROUTE_ITEMS {
        bail!(
            "{}: {items} routed values exceed the {MAX_ROUTE_ITEMS} emission budget — use pipeline::analyze",
            net.name
        );
    }
    pass_span(
        "decide_layer",
        t0,
        vec![
            ("layers".into(), Json::Int(decisions.len() as i64)),
            ("route_items".into(), Json::Int(items as i64)),
        ],
    );
    // Passes 2 + 4: weights + numeric batch-norm fold, then per-layer
    // compression (structured pruning + INT-k quantization) onto the
    // shared decisions.
    let t0 = tr.map(|t| t.begin());
    let weights = NetworkWeights::synthetic(net, opts.seed)?.fold(&norm)?;
    let lowered = lower_layers(&norm, &weights, &decisions, model, opts)?;
    pass_span("compress", t0, vec![("layers".into(), Json::Int(lowered.len() as i64))]);
    // Pass 5: emission + the analytic view over the same decisions.
    // decide_layer is pure, so cost_network's internal decisions must
    // equal ours; verify rather than assume, so a future stateful
    // decision can't silently split the two paths.
    let t0 = tr.map(|t| t.begin());
    let cost = cost_network(model, &norm.net)?;
    for (d, lc) in decisions.iter().zip(&cost.layers) {
        if d.case != lc.case {
            bail!("internal: mapping disagreement on {} ({:?} vs {:?})", lc.name, d.case, lc.case);
        }
    }
    let program = emit_program(
        &norm.net.name,
        &lowered,
        shapes[0].flat(),
        shapes.last().unwrap().flat(),
        model,
        opts,
    )?;
    pass_span(
        "emit",
        t0,
        vec![
            ("insns".into(), Json::Int(program.insns.len() as i64)),
            ("data_segments".into(), Json::Int(program.data.len() as i64)),
        ],
    );
    Ok(CompiledNetwork {
        name: net.name.clone(),
        model: model.clone(),
        program,
        lowered,
        decisions,
        cost,
        in_scale: opts.in_scale,
        bits: model.bits,
    })
}

/// Can this layer's mapping be emitted, or is it analytic-only?
/// Tiled FC/conv mappings (§4.4.3-II) lower through per-tile waves and
/// runtime `FoldAdd` partial-sum buffers, so only attention (and a
/// batch norm that escaped normalization) remain non-executable.
fn ensure_executable(l: &crate::nn::Layer, d: &MappingDecision) -> Result<()> {
    match &l.kind {
        LayerKind::Fc { .. } | LayerKind::Conv { .. } => {
            if let LayerKind::Conv { groups, .. } = &l.kind {
                if d.groups != *groups && *groups > 1 {
                    bail!(
                        "{}: dense lowering of a {groups}-group conv is unsupported (enable group_conv)",
                        l.name
                    );
                }
            }
            Ok(())
        }
        LayerKind::MaxPool { .. } => Ok(()),
        LayerKind::BatchNorm => bail!("{}: batch norm survived normalization (fold it first)", l.name),
        LayerKind::Attention { .. } => {
            bail!("{}: attention mapping (§4.4.4) is analytic-only — use pipeline::analyze", l.name)
        }
    }
}

/// Pass 4: per-layer compression + lowering onto the shared mapping.
fn lower_layers(
    norm: &Normalized,
    weights: &NetworkWeights,
    decisions: &[MappingDecision],
    model: &CostModel,
    opts: &PipelineOptions,
) -> Result<Vec<Lowered>> {
    let net = &norm.net;
    let shapes = net.shapes()?;
    let mut rng = Rng::new(opts.seed ^ 0x9e37_79b9_7f4a_7c15);
    let mut lowered = Vec::with_capacity(net.layers.len());
    let last = net.layers.len() - 1;
    for (i, l) in net.layers.iter().enumerate() {
        let (inp, outp) = (shapes[i], shapes[i + 1]);
        let d = &decisions[i];
        ensure_executable(l, d)?;
        match (&l.kind, &weights.layers[i]) {
            (LayerKind::Fc { dout }, LayerParams::Fc { w, b }) => {
                let structure = BlockStructure::random(*dout, inp.flat(), d.nb, &mut rng)?;
                // Column-tiled blocks (§4.4.3-II) are quantized on the
                // host after the fold, which applies one scale to the
                // whole stream: the lowering must be uniform.
                let out_scale: Vec<f32> = if i == last {
                    vec![0.0; d.nb]
                } else if d.tw > 1 {
                    vec![0.1 + rng.f64() as f32 * 0.4; d.nb]
                } else {
                    (0..d.nb).map(|_| 0.1 + rng.f64() as f32 * 0.4).collect()
                };
                let packed = PackedLayer::quantize_from(structure, model.bits, w, b, out_scale, l.relu)?;
                lowered.push(Lowered::Fc(packed));
            }
            (LayerKind::Conv { cout, kh, kw, stride, padding, .. }, LayerParams::Conv { w, b }) => {
                let g = d.groups;
                let bh = cout / g;
                let kvol = kh * kw * (inp.c / g);
                // As for FCs: a column-tiled conv is quantized by the
                // host epilogue, so its out_scale must be uniform.
                let shared_os = (d.tw > 1 && i != last).then(|| 0.1 + rng.f64() as f32 * 0.4);
                let mut codes = Vec::with_capacity(g);
                let mut w_scale = Vec::with_capacity(g);
                let mut bias = Vec::with_capacity(g);
                let mut out_scale = Vec::with_capacity(g);
                for q in 0..g {
                    let block = &w[q * bh * kvol..(q + 1) * bh * kvol];
                    let qz = Quantizer::calibrate(model.bits, block);
                    codes.push(block.iter().map(|&x| qz.quantize(x) as i8).collect());
                    w_scale.push(qz.scale);
                    bias.push(b[q * bh..(q + 1) * bh].to_vec());
                    out_scale.push(match (i == last, shared_os) {
                        (true, _) => 0.0,
                        (false, Some(os)) => os,
                        (false, None) => 0.1 + rng.f64() as f32 * 0.4,
                    });
                }
                let cv = ConvLayer {
                    name: l.name.clone(),
                    in_h: inp.h,
                    in_w: inp.w,
                    in_c: inp.c,
                    cout: *cout,
                    kh: *kh,
                    kw: *kw,
                    stride: *stride,
                    padding: *padding,
                    groups: g,
                    oh: outp.h,
                    ow: outp.w,
                    codes,
                    w_scale,
                    bias,
                    out_scale,
                    relu: l.relu,
                    bits: model.bits,
                    tile_h: model.pe_h,
                    tile_w: model.pe_w,
                };
                if cv.th() != d.th || cv.tw() != d.tw {
                    bail!(
                        "internal: {} tiling disagreement ({}×{} vs decision {}×{})",
                        l.name,
                        cv.th(),
                        cv.tw(),
                        d.th,
                        d.tw
                    );
                }
                lowered.push(Lowered::Conv(cv));
            }
            (LayerKind::MaxPool { window, stride }, _) => {
                lowered.push(Lowered::Pool { h: inp.h, w: inp.w, c: inp.c, window: *window, stride: *stride });
            }
            _ => bail!("{}: weights do not match layer kind", l.name),
        }
    }
    Ok(lowered)
}

// ---------------------------------------------------------------------------
// Emission (pass 5)
// ---------------------------------------------------------------------------

fn emit_program(
    name: &str,
    lowered: &[Lowered],
    din: usize,
    dout: usize,
    model: &CostModel,
    opts: &PipelineOptions,
) -> Result<Program> {
    let n_pes = model.n_pes;
    let mut p = Program { name: name.to_string(), din, dout, ..Default::default() };

    // Ingress quantizer on the host core.
    let q_seg = p.push_data(DataSegment::F32(vec![opts.in_scale, model.bits as f32]));
    p.insns.push(Insn::HostOp { op: HostOpKind::Quantize, seg: q_seg });

    let mut producers: std::borrow::Cow<'_, [Vec<u32>]> =
        std::borrow::Cow::Owned(input_chunks(din, n_pes));
    let mut from_input = true;
    for (li, low) in lowered.iter().enumerate() {
        match low {
            Lowered::Fc(packed) => {
                producers = emit_packed_fc(
                    &mut p,
                    li as u16,
                    packed,
                    &producers,
                    from_input,
                    n_pes,
                    model.pe_h,
                    model.pe_w,
                )?;
            }
            Lowered::Conv(cv) => {
                producers = std::borrow::Cow::Owned(emit_conv(&mut p, li as u16, cv, n_pes)?);
            }
            Lowered::Pool { h, w, c, window, stride } => {
                let seg = p.push_data(DataSegment::F32(vec![
                    *h as f32,
                    *w as f32,
                    *c as f32,
                    *window as f32,
                    *stride as f32,
                ]));
                p.insns.push(Insn::HostOp { op: HostOpKind::MaxPool, seg });
                let oh = (h - window) / stride + 1;
                let ow = (w - window) / stride + 1;
                producers = std::borrow::Cow::Owned(input_chunks(oh * ow * c, n_pes));
            }
        }
        from_input = false;
    }
    p.insns.push(Insn::Halt);
    if p.data.len() > u16::MAX as usize {
        bail!("{name}: {} data segments overflow the 16-bit segment table", p.data.len());
    }
    p.validate()?;
    Ok(p)
}

/// Emit one lowered convolution: host `Gather` materializes the padded
/// plane, then positions run as waves of per-PE mat-vecs. Groups are
/// PE-stationary — with `g` groups on `n` PEs, each wave computes
/// `min(g,n)` groups × `max(1, n/g)` positions, so weights load once per
/// group chunk (plus one reload for a ragged tail wave) and the wave
/// count matches the analytic model's `ceil(positions·g / n)` whenever
/// `g` and `n` divide evenly.
///
/// A group block larger than one PE is tiled (§4.4.3-II): every
/// `(row tile, column tile)` pair runs its own wave sequence, column
/// tile `t` scatters into host buffer `t` (tile 0 into the pending
/// stream, bias attached), and the layer ends with runtime `FoldAdd`
/// ops plus a host ReLU/quantize epilogue.
fn emit_conv(p: &mut Program, layer_id: u16, cv: &ConvLayer, n_pes: usize) -> Result<Vec<Vec<u32>>> {
    let (h, w, c, pad) = (cv.in_h, cv.in_w, cv.in_c, cv.padding);
    let (ph, pw) = (h + 2 * pad, w + 2 * pad);
    let (g, bh, kvol) = (cv.groups, cv.bh(), cv.kvol());
    let cin_g = c / g;
    let positions = cv.oh * cv.ow;
    let dout = positions * cv.cout;
    let (th, tw) = (cv.th(), cv.tw());

    // Host gather: padded input plane (negative index = implicit zero).
    // Gather parameters ride an f32 segment, which is only exact for
    // indices below 2^24 — refuse planes past that rather than letting
    // rounded indices read the wrong activation.
    if ((ph * pw * c) as u64) >= (1 << 24) {
        bail!("{}: padded plane of {} values exceeds the f32-exact gather index range", cv.name, ph * pw * c);
    }
    let mut idx = Vec::with_capacity(ph * pw * c);
    for y in 0..ph {
        for x in 0..pw {
            for ch in 0..c {
                let (iy, ix) = (y as isize - pad as isize, x as isize - pad as isize);
                let inside = iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w;
                idx.push(if inside { ((iy as usize * w + ix as usize) * c + ch) as f32 } else { -1.0 });
            }
        }
    }
    let g_seg = p.push_data(DataSegment::F32(idx));
    p.insns.push(Insn::HostOp { op: HostOpKind::Gather, seg: g_seg });

    // Padded-plane producers: host-owned, chunked across crossbar wires.
    let padded_chunks = input_chunks(ph * pw * c, n_pes);

    // One weight/bias/scale segment per (group, row tile, column tile),
    // shared across waves. Bias rides column tile 0; with column tiles
    // the PE-side activation (ReLU + quantizer) defers to the host
    // epilogue after the last fold.
    let mut w_segs = vec![vec![vec![0u16; tw]; th]; g];
    let mut b_segs = vec![vec![vec![0u16; tw]; th]; g];
    let mut s_segs = vec![vec![vec![0u16; tw]; th]; g];
    for q in 0..g {
        for r in 0..th {
            let r0 = r * cv.tile_h.min(bh);
            let rows = cv.tile_h.min(bh - r0);
            for t in 0..tw {
                let c0 = t * cv.tile_w.min(kvol);
                let cols = cv.tile_w.min(kvol - c0);
                let mut tile = Vec::with_capacity(rows * cols);
                for i in 0..rows {
                    let base = (r0 + i) * kvol + c0;
                    tile.extend_from_slice(&cv.codes[q][base..base + cols]);
                }
                w_segs[q][r][t] = p.push_data(DataSegment::I8(tile));
                let bias: Vec<f32> =
                    if t == 0 { cv.bias[q][r0..r0 + rows].to_vec() } else { vec![0.0; rows] };
                b_segs[q][r][t] = p.push_data(DataSegment::F32(bias));
                let os = if tw == 1 { cv.out_scale[q] } else { 0.0 };
                s_segs[q][r][t] = p.push_data(DataSegment::F32(vec![cv.w_scale[q], os]));
            }
        }
    }

    let mut owners: Vec<Vec<u32>> = vec![Vec::new(); n_pes];
    for t in 0..tw {
        let c0 = t * cv.tile_w.min(kvol);
        let cols = cv.tile_w.min(kvol - c0);
        for r in 0..th {
            let r0 = r * cv.tile_h.min(bh);
            let rows = cv.tile_h.min(bh - r0);
            let mut q0 = 0;
            while q0 < g {
                let cg = (g - q0).min(n_pes); // groups in this chunk
                let reps = (n_pes / cg).max(1); // positions per wave
                let mut pos0 = 0;
                let mut cur_nb = 0usize;
                while pos0 < positions {
                    let reps_here = reps.min(positions - pos0);
                    let nb = cg * reps_here;
                    if nb != cur_nb {
                        // (Re)configure the wave shape; PE weight SRAMs are
                        // cleared by ConfigLayer, so reload the chunk's groups.
                        p.insns.push(Insn::ConfigLayer {
                            layer: layer_id,
                            nb: nb as u16,
                            bh: rows as u16,
                            bw: cols as u16,
                            bits: cv.bits as u8,
                            relu: cv.relu && tw == 1,
                        });
                        for pe in 0..nb {
                            let q = q0 + pe % cg;
                            p.insns.push(Insn::LoadWeights { pe: pe as u16, seg: w_segs[q][r][t] });
                            p.insns.push(Insn::LoadBias { pe: pe as u16, seg: b_segs[q][r][t] });
                            p.insns.push(Insn::SetScales { pe: pe as u16, seg: s_segs[q][r][t] });
                        }
                        cur_nb = nb;
                    }
                    // Routing demand: PE pe latches its tile's slice of the
                    // im2col window of its (position, group) job; slot j of
                    // the unrolled kernel is (ky, kx, ci-within-group).
                    let mut consumers = Vec::with_capacity(nb);
                    for pe in 0..nb {
                        let q = q0 + pe % cg;
                        let pos = pos0 + pe / cg;
                        let (oy, ox) = (pos / cv.ow, pos % cv.ow);
                        let mut want = Vec::with_capacity(cols);
                        for slot in c0..c0 + cols {
                            let ky = slot / (cv.kw * cin_g);
                            let kx = (slot / cin_g) % cv.kw;
                            let ci = slot % cin_g;
                            let (y, x) = (oy * cv.stride + ky, ox * cv.stride + kx);
                            want.push(((y * pw + x) * c + q * cin_g + ci) as u32);
                        }
                        consumers.push(want);
                    }
                    let demand = build_demand(&padded_chunks, &consumers)?;
                    let sched = schedule_routes(&demand)?;
                    sched.verify(&demand)?;
                    let r_seg = p.push_data(DataSegment::Routes(sched.assignments));
                    p.insns.push(Insn::Route { seg: r_seg, from_input: false });
                    p.insns.push(Insn::Compute { rows: rows as u16 });
                    // Scatter: channel-last output layout, owner = wave PE
                    // index; column tile t lands in host buffer t.
                    let mut scat = Vec::with_capacity(1 + nb * rows);
                    scat.push(dout as u32);
                    for pe in 0..nb {
                        let q = q0 + pe % cg;
                        let pos = pos0 + pe / cg;
                        for i in 0..rows {
                            let gidx = (pos * cv.cout + q * bh + r0 + i) as u32;
                            scat.push(gidx);
                            if t == 0 {
                                owners[pe].push(gidx);
                            }
                        }
                    }
                    let sc_seg = p.push_data(DataSegment::U32(scat));
                    p.insns.push(Insn::Scatter { seg: sc_seg, buf: t as u16 });
                    if p.data.len() + 8 > u16::MAX as usize {
                        bail!("{}: conv emission overflows the segment table", cv.name);
                    }
                    pos0 += reps_here;
                }
                q0 += cg;
            }
        }
    }
    if tw > 1 {
        emit_fold_epilogue(p, tw, cv.relu, cv.out_scale[0], cv.bits);
        // Folded outputs are host-owned: chunk them across wires.
        return Ok(input_chunks(dout, n_pes));
    }
    Ok(owners)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::graph::{Layer, Shape};
    use crate::nn::zoo;
    use crate::sim::Apu;

    fn conv_layer(name: &str, cout: usize, k: usize, groups: usize, padding: usize, relu: bool) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv { cout, kh: k, kw: k, stride: 1, groups, padding },
            relu,
        }
    }

    #[test]
    fn bn_fold_preserves_float_semantics() {
        let net = Network {
            name: "fold".into(),
            input: Shape { h: 6, w: 6, c: 4 },
            layers: vec![
                conv_layer("conv", 8, 3, 2, 1, false),
                Layer { name: "bn".into(), kind: LayerKind::BatchNorm, relu: true },
                Layer { name: "fc".into(), kind: LayerKind::Fc { dout: 10 }, relu: false },
            ],
        };
        let weights = NetworkWeights::synthetic(&net, 11).unwrap();
        let norm = normalize(&net).unwrap();
        let folded = weights.clone().fold(&norm).unwrap();
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..6 * 6 * 4).map(|_| rng.normal()).collect();
        let want = float_forward(&net, &weights, &x).unwrap();
        let got = float_forward(&norm.net, &folded, &x).unwrap();
        assert_eq!(want.len(), got.len());
        for (i, (&a, &b)) in want.iter().zip(&got).enumerate() {
            assert!((a - b).abs() < 1e-4, "output {i}: {a} vs {b}");
        }
    }

    #[test]
    fn single_conv_simulates_exactly() {
        // One grouped conv: the sim must reproduce the lowered reference
        // bit-for-bit (routing, latching, PE datapath, scatter).
        let net = Network {
            name: "conv1".into(),
            input: Shape { h: 6, w: 6, c: 4 },
            layers: vec![conv_layer("c", 8, 3, 2, 1, true)],
        };
        let model = CostModel::nano_4pe();
        let compiled = compile_network(&net, &model, &PipelineOptions::default()).unwrap();
        assert_eq!(compiled.decisions[0].case, MappingCase::ConvGroup);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..6 * 6 * 4).map(|_| rng.normal()).collect();
        let want = compiled.reference_forward(&x).unwrap();
        let mut apu = Apu::new(model.apu_config());
        apu.load(&compiled.program).unwrap();
        let got = apu.run(&x).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (&a, &b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-5, "output {i}: {a} vs {b}");
        }
    }

    #[test]
    fn dense_fc_fallback_simulates_exactly() {
        // 12→7: 7 is indivisible by fc_blocks=4, so the mapping falls back
        // to a dense nb=1 block on one PE.
        let net = Network {
            name: "dense".into(),
            input: Shape { h: 1, w: 1, c: 12 },
            layers: vec![Layer { name: "fc".into(), kind: LayerKind::Fc { dout: 7 }, relu: true }],
        };
        let model = CostModel::nano_4pe();
        let compiled = compile_network(&net, &model, &PipelineOptions::default()).unwrap();
        assert_eq!(compiled.decisions[0].case, MappingCase::FcDense);
        let x: Vec<f32> = (0..12).map(|i| (i as f32 * 0.37).sin()).collect();
        let want = compiled.reference_forward(&x).unwrap();
        let mut apu = Apu::new(model.apu_config());
        apu.load(&compiled.program).unwrap();
        let got = apu.run(&x).unwrap();
        for (i, (&a, &b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-5, "output {i}: {a} vs {b}");
        }
    }

    #[test]
    fn case_ii_conv_now_compiles_attention_stays_analytic() {
        let model = CostModel::nano_4pe();
        // a conv whose unrolled kernel exceeds one PE → case II, which
        // now lowers through per-tile waves + runtime FoldAdd
        let big = Network {
            name: "big".into(),
            input: Shape { h: 8, w: 8, c: 64 },
            layers: vec![conv_layer("c", 64, 5, 1, 2, true)],
        };
        let compiled = compile_network(&big, &model, &PipelineOptions::default()).unwrap();
        assert_eq!(compiled.decisions[0].case, MappingCase::ConvLarge);
        assert!(!compiled.decisions[0].fits_one_pe());
        // the program carries the fold machinery
        let folds = compiled
            .program
            .insns
            .iter()
            .filter(|i| matches!(i, Insn::HostOp { op: HostOpKind::FoldAdd, .. }))
            .count();
        assert_eq!(folds, compiled.decisions[0].tw - 1);
        // attention remains analytic-only
        let mha = zoo::transformer_mha(4, 64, 8);
        assert!(compile_network(&mha, &model, &PipelineOptions::default()).is_err());
        assert!(analyze(&mha, &model).is_ok());
    }

    #[test]
    fn tracer_records_one_span_per_pass() {
        let tracer = Tracer::new();
        let opts = PipelineOptions { tracer: Some(tracer.clone()), ..Default::default() };
        let model = CostModel::nano_4pe();
        compile_network(&zoo::vgg_nano(), &model, &opts).unwrap();
        let events = tracer.events();
        for want in ["normalize", "decide_layer", "compress", "emit"] {
            let n = events.iter().filter(|e| e.name == want && e.cat == "compiler").count();
            assert_eq!(n, 1, "expected exactly one {want} span");
        }
    }

    #[test]
    fn pipeline_and_cost_model_share_mapping_cases() {
        let model = CostModel::nano_4pe();
        let compiled =
            compile_network(&zoo::vgg_nano(), &model, &PipelineOptions::default()).unwrap();
        assert_eq!(compiled.decisions.len(), compiled.cost.layers.len());
        for (d, lc) in compiled.decisions.iter().zip(&compiled.cost.layers) {
            assert_eq!(d.case, lc.case, "{}: emitter/cost disagree", lc.name);
        }
        let table = compiled.table();
        assert!(table.contains("ConvGroup") && table.contains("TOTAL"), "{table}");
    }
}
