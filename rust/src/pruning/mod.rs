//! Structured pruning: permuted-identity masks, block decomposition, and
//! the INT-k quantizer (paper §2, Eq. (1), Fig. 1).
//!
//! This is the rust mirror of `python/compile/masks.py` + `quant.py`: the
//! compiler uses it to decompose *dense* imported layers (and to generate
//! synthetic workloads for the figure benches), and the simulator uses the
//! quantizer as its integer datapath reference. The python and rust sides
//! are kept behaviourally identical; `rust/tests/integration_golden.rs`
//! pins the cross-language agreement through the artifact bundle.

pub mod blocks;
pub mod quant;

pub use blocks::{BlockStructure, PackedLayer};
pub use quant::Quantizer;
