//! Symmetric INT-k quantizer — the simulator's integer datapath reference
//! (paper §2.2). Mirrors `python/compile/kernels/quant.py` exactly:
//! round-half-to-even, saturate at ±(2^(k-1)-1).

/// Symmetric signed quantizer with a fixed scale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantizer {
    pub bits: u32,
    pub scale: f32,
}

impl Quantizer {
    pub fn new(bits: u32, scale: f32) -> Quantizer {
        assert!(bits >= 2, "quantization needs >=2 bits");
        assert!(scale > 0.0, "scale must be positive");
        Quantizer { bits, scale }
    }

    /// Largest positive code: 4 bits → 7 (sign-magnitude-friendly grid).
    pub fn qmax(bits: u32) -> i32 {
        (1 << (bits - 1)) - 1
    }

    /// Fit a per-tensor scale so max|x| hits the top code.
    pub fn calibrate(bits: u32, xs: &[f32]) -> Quantizer {
        let amax = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let amax = if amax == 0.0 { 1.0 } else { amax };
        Quantizer::new(bits, amax / Self::qmax(bits) as f32)
    }

    /// Float → integer code (round-half-even, saturating).
    #[inline]
    pub fn quantize(&self, x: f32) -> i32 {
        let q = Self::qmax(self.bits);
        let r = round_half_even(x / self.scale);
        r.clamp(-q, q)
    }

    /// Integer code → float grid point.
    #[inline]
    pub fn dequantize(&self, code: i32) -> f32 {
        code as f32 * self.scale
    }

    /// Quantize-dequantize: snap to the INT-k grid.
    #[inline]
    pub fn fake(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }
}

/// Round half to even, matching `jnp.round` / numpy semantics so the rust
/// integer datapath agrees with the python-exported codes bit-for-bit.
#[inline]
fn round_half_even(x: f32) -> i32 {
    let floor = x.floor();
    let diff = x - floor;
    let f = floor as i32;
    if diff > 0.5 {
        f + 1
    } else if diff < 0.5 {
        f
    } else if f % 2 == 0 {
        f
    } else {
        f + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn qmax_values() {
        assert_eq!(Quantizer::qmax(4), 7);
        assert_eq!(Quantizer::qmax(8), 127);
        assert_eq!(Quantizer::qmax(16), 32767);
    }

    #[test]
    fn round_half_even_matches_numpy() {
        // numpy: round(0.5)=0, round(1.5)=2, round(2.5)=2, round(-0.5)=0, round(-1.5)=-2
        assert_eq!(round_half_even(0.5), 0);
        assert_eq!(round_half_even(1.5), 2);
        assert_eq!(round_half_even(2.5), 2);
        assert_eq!(round_half_even(-0.5), 0);
        assert_eq!(round_half_even(-1.5), -2);
        assert_eq!(round_half_even(0.49), 0);
        assert_eq!(round_half_even(0.51), 1);
        assert_eq!(round_half_even(-2.5), -2);
    }

    #[test]
    fn saturates_at_qmax() {
        let q = Quantizer::new(4, 0.1);
        assert_eq!(q.quantize(100.0), 7);
        assert_eq!(q.quantize(-100.0), -7);
    }

    #[test]
    fn calibrated_error_within_half_lsb() {
        let mut rng = Rng::new(11);
        let xs: Vec<f32> = (0..256).map(|_| rng.normal() * 3.0).collect();
        let q = Quantizer::calibrate(4, &xs);
        for &x in &xs {
            assert!((q.fake(x) - x).abs() <= q.scale / 2.0 + 1e-6);
        }
    }

    #[test]
    fn idempotent_on_grid() {
        let mut rng = Rng::new(12);
        let q = Quantizer::new(4, 0.37);
        for _ in 0..200 {
            let x = rng.uniform(-3.0, 3.0);
            let y = q.fake(x);
            assert_eq!(q.fake(y), y);
        }
    }

    #[test]
    fn all_zero_calibration_is_safe() {
        let q = Quantizer::calibrate(4, &[0.0; 8]);
        assert_eq!(q.quantize(0.0), 0);
    }

    #[test]
    fn monotone() {
        let q = Quantizer::new(4, 0.5);
        let mut prev = i32::MIN;
        let mut x = -5.0f32;
        while x < 5.0 {
            let c = q.quantize(x);
            assert!(c >= prev);
            prev = c;
            x += 0.01;
        }
    }
}
