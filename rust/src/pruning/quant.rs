//! Symmetric INT-k quantizer — the simulator's integer datapath reference
//! (paper §2.2). Mirrors `python/compile/kernels/quant.py` exactly:
//! round-half-to-even, saturate at ±(2^(k-1)-1).

/// Symmetric signed quantizer with a fixed scale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantizer {
    pub bits: u32,
    pub scale: f32,
}

impl Quantizer {
    pub fn new(bits: u32, scale: f32) -> Quantizer {
        assert!(bits >= 2, "quantization needs >=2 bits");
        assert!(scale > 0.0, "scale must be positive");
        Quantizer { bits, scale }
    }

    /// Largest positive code: 4 bits → 7 (sign-magnitude-friendly grid).
    pub fn qmax(bits: u32) -> i32 {
        (1 << (bits - 1)) - 1
    }

    /// Fit a per-tensor scale so max|x| hits the top code.
    pub fn calibrate(bits: u32, xs: &[f32]) -> Quantizer {
        let amax = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let amax = if amax == 0.0 { 1.0 } else { amax };
        Quantizer::new(bits, amax / Self::qmax(bits) as f32)
    }

    /// Float → integer code (round-half-even, saturating).
    #[inline]
    pub fn quantize(&self, x: f32) -> i32 {
        let q = Self::qmax(self.bits);
        let r = round_half_even(x / self.scale);
        r.clamp(-q, q)
    }

    /// Integer code → float grid point.
    #[inline]
    pub fn dequantize(&self, code: i32) -> f32 {
        code as f32 * self.scale
    }

    /// Quantize-dequantize: snap to the INT-k grid. Stays in the float
    /// domain (round, clamp, rescale — no int round-trip), which is the
    /// form the compiler auto-vectorizes; for every finite input the
    /// result is bitwise identical to `dequantize(quantize(x))` because
    /// the clamped code is an integer ≤ 32767, exact in f32 either way.
    #[inline]
    pub fn fake(&self, x: f32) -> f32 {
        let q = Self::qmax(self.bits) as f32;
        round_half_even_f32(x / self.scale).clamp(-q, q) * self.scale
    }

    /// Snap a whole buffer to the INT-k grid in place — the hot-loop form
    /// of [`Quantizer::fake`] (PE output quantizers, host `Quantize`
    /// ops). One round/clamp/mul lane per element, no data dependence
    /// between lanes, so LLVM vectorizes it; elementwise it is the exact
    /// same expression as the scalar path.
    pub fn fake_slice(&self, xs: &mut [f32]) {
        let q = Self::qmax(self.bits) as f32;
        for x in xs.iter_mut() {
            *x = round_half_even_f32(*x / self.scale).clamp(-q, q) * self.scale;
        }
    }
}

/// Round half to even, matching `jnp.round` / numpy semantics so the rust
/// integer datapath agrees with the python-exported codes bit-for-bit.
#[inline]
fn round_half_even(x: f32) -> i32 {
    let floor = x.floor();
    let diff = x - floor;
    let f = floor as i32;
    if diff > 0.5 {
        f + 1
    } else if diff < 0.5 {
        f
    } else if f % 2 == 0 {
        f
    } else {
        f + 1
    }
}

/// [`round_half_even`] without the int round-trip: same tie-to-even
/// semantics, result kept in f32 so the caller can clamp/rescale in the
/// float domain. Agrees with the int path on every finite input: ties
/// (`diff == 0.5`) only exist below 2^23 where `floor as i64` is exact,
/// and NaN maps to 0 exactly like the saturating `as i32` cast.
#[inline]
fn round_half_even_f32(x: f32) -> f32 {
    if x.is_nan() {
        return 0.0;
    }
    let floor = x.floor();
    let diff = x - floor;
    if diff > 0.5 {
        floor + 1.0
    } else if diff < 0.5 {
        floor
    } else if (floor as i64) % 2 == 0 {
        floor
    } else {
        floor + 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn qmax_values() {
        assert_eq!(Quantizer::qmax(4), 7);
        assert_eq!(Quantizer::qmax(8), 127);
        assert_eq!(Quantizer::qmax(16), 32767);
    }

    #[test]
    fn round_half_even_matches_numpy() {
        // numpy: round(0.5)=0, round(1.5)=2, round(2.5)=2, round(-0.5)=0, round(-1.5)=-2
        assert_eq!(round_half_even(0.5), 0);
        assert_eq!(round_half_even(1.5), 2);
        assert_eq!(round_half_even(2.5), 2);
        assert_eq!(round_half_even(-0.5), 0);
        assert_eq!(round_half_even(-1.5), -2);
        assert_eq!(round_half_even(0.49), 0);
        assert_eq!(round_half_even(0.51), 1);
        assert_eq!(round_half_even(-2.5), -2);
    }

    #[test]
    fn saturates_at_qmax() {
        let q = Quantizer::new(4, 0.1);
        assert_eq!(q.quantize(100.0), 7);
        assert_eq!(q.quantize(-100.0), -7);
    }

    #[test]
    fn calibrated_error_within_half_lsb() {
        let mut rng = Rng::new(11);
        let xs: Vec<f32> = (0..256).map(|_| rng.normal() * 3.0).collect();
        let q = Quantizer::calibrate(4, &xs);
        for &x in &xs {
            assert!((q.fake(x) - x).abs() <= q.scale / 2.0 + 1e-6);
        }
    }

    #[test]
    fn idempotent_on_grid() {
        let mut rng = Rng::new(12);
        let q = Quantizer::new(4, 0.37);
        for _ in 0..200 {
            let x = rng.uniform(-3.0, 3.0);
            let y = q.fake(x);
            assert_eq!(q.fake(y), y);
        }
    }

    #[test]
    fn all_zero_calibration_is_safe() {
        let q = Quantizer::calibrate(4, &[0.0; 8]);
        assert_eq!(q.quantize(0.0), 0);
    }

    #[test]
    fn fake_agrees_with_int_round_trip_bitwise() {
        // `fake` now stays in the float domain; it must still equal the
        // int-path reference on a dense sweep that crosses every tie.
        for bits in [2u32, 4, 8, 16] {
            for &scale in &[0.1f32, 0.25, 0.37, 1.0] {
                let q = Quantizer::new(bits, scale);
                let mut x = -9.0f32;
                while x < 9.0 {
                    let via_int = q.dequantize(q.quantize(x));
                    assert_eq!(q.fake(x).to_bits(), via_int.to_bits(), "bits={bits} scale={scale} x={x}");
                    x += 0.001953125; // 2^-9: hits exact .5/scale ties
                }
            }
        }
    }

    #[test]
    fn fake_slice_matches_scalar_fake_bitwise() {
        let mut rng = Rng::new(13);
        let q = Quantizer::new(4, 0.37);
        let mut xs: Vec<f32> = (0..4096).map(|_| rng.normal() * 2.0).collect();
        xs.extend([0.5 * 0.37, -0.5 * 0.37, 1.5 * 0.37, 100.0, -100.0, 0.0, f32::NAN]);
        let want: Vec<f32> = xs.iter().map(|&x| q.fake(x)).collect();
        q.fake_slice(&mut xs);
        for (i, (&g, &w)) in xs.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "element {i}");
        }
        // NaN input snaps to code 0, same as the saturating int cast
        assert_eq!(q.fake(f32::NAN), 0.0);
    }

    #[test]
    fn monotone() {
        let q = Quantizer::new(4, 0.5);
        let mut prev = i32::MIN;
        let mut x = -5.0f32;
        while x < 5.0 {
            let c = q.quantize(x);
            assert!(c >= prev);
            prev = c;
            x += 0.01;
        }
    }
}
