//! Block-diagonal decomposition of structured-pruned layers (paper §2.1).
//!
//! [`BlockStructure`] partitions a layer's rows (outputs) and columns
//! (inputs) into `nb` balanced groups; weight `(r, c)` survives pruning iff
//! `group(r) == group(c)`. [`PackedLayer`] carries the per-block dense
//! sub-matrices as INT-k codes plus scales — exactly what each PE holds in
//! its local weight SRAM.

use anyhow::{bail, Result};

use super::quant::Quantizer;
use crate::util::rng::Rng;

/// Balanced random row/column partition inducing the block-diagonal mask.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockStructure {
    pub dout: usize,
    pub din: usize,
    pub nb: usize,
    /// `row_groups[g]` = sorted original row indices owned by block `g`.
    pub row_groups: Vec<Vec<u32>>,
    /// `col_groups[g]` = sorted original column indices owned by block `g`.
    pub col_groups: Vec<Vec<u32>>,
}

impl BlockStructure {
    /// Randomly partition `dout × din` into `nb` balanced groups
    /// (mirror of `python/compile/masks.py::make_structure`).
    pub fn random(dout: usize, din: usize, nb: usize, rng: &mut Rng) -> Result<BlockStructure> {
        if nb == 0 || dout % nb != 0 || din % nb != 0 {
            bail!("dims ({dout},{din}) not divisible by nb={nb}");
        }
        let rp = rng.permutation(dout);
        let cp = rng.permutation(din);
        let bh = dout / nb;
        let bw = din / nb;
        let mut row_groups: Vec<Vec<u32>> = rp.chunks(bh).map(|c| c.to_vec()).collect();
        let mut col_groups: Vec<Vec<u32>> = cp.chunks(bw).map(|c| c.to_vec()).collect();
        for g in &mut row_groups {
            g.sort_unstable();
        }
        for g in &mut col_groups {
            g.sort_unstable();
        }
        Ok(BlockStructure { dout, din, nb, row_groups, col_groups })
    }

    /// Rebuild a structure from flat permutations (as exported by the
    /// python bundle: `col_perm`/`row_perm` are group-major).
    pub fn from_flat_perms(dout: usize, din: usize, nb: usize, row_perm: &[u32], col_perm: &[u32]) -> Result<BlockStructure> {
        if row_perm.len() != dout || col_perm.len() != din {
            bail!("permutation lengths ({}, {}) mismatch dims ({dout}, {din})", row_perm.len(), col_perm.len());
        }
        if nb == 0 || dout % nb != 0 || din % nb != 0 {
            bail!("dims ({dout},{din}) not divisible by nb={nb}");
        }
        let check_bijection = |p: &[u32], n: usize| -> Result<()> {
            let mut seen = vec![false; n];
            for &i in p {
                let i = i as usize;
                if i >= n || seen[i] {
                    bail!("not a permutation of 0..{n}");
                }
                seen[i] = true;
            }
            Ok(())
        };
        check_bijection(row_perm, dout)?;
        check_bijection(col_perm, din)?;
        let row_groups = row_perm.chunks(dout / nb).map(|c| c.to_vec()).collect();
        let col_groups = col_perm.chunks(din / nb).map(|c| c.to_vec()).collect();
        Ok(BlockStructure { dout, din, nb, row_groups, col_groups })
    }

    pub fn bh(&self) -> usize {
        self.dout / self.nb
    }

    pub fn bw(&self) -> usize {
        self.din / self.nb
    }

    /// Density of the induced mask = 1/nb.
    pub fn density(&self) -> f64 {
        1.0 / self.nb as f64
    }

    /// Flat input permutation (group-major): `a_packed[i] = a[col_perm[i]]`.
    pub fn col_perm(&self) -> Vec<u32> {
        self.col_groups.iter().flatten().copied().collect()
    }

    /// Flat output permutation: `o_full[row_perm[i]] = o_packed[i]`.
    pub fn row_perm(&self) -> Vec<u32> {
        self.row_groups.iter().flatten().copied().collect()
    }

    /// The Eq. (1) binary mask, row-major `dout × din`.
    pub fn mask(&self) -> Vec<u8> {
        let mut m = vec![0u8; self.dout * self.din];
        for g in 0..self.nb {
            for &r in &self.row_groups[g] {
                let base = r as usize * self.din;
                for &c in &self.col_groups[g] {
                    m[base + c as usize] = 1;
                }
            }
        }
        m
    }

    /// Extract the dense per-block sub-matrices from a full matrix
    /// (row-major `dout × din`) — the Fig. 1 packing.
    pub fn pack(&self, w_full: &[f32]) -> Result<Vec<Vec<f32>>> {
        if w_full.len() != self.dout * self.din {
            bail!("weight len {} != {}x{}", w_full.len(), self.dout, self.din);
        }
        let mut blocks = Vec::with_capacity(self.nb);
        for g in 0..self.nb {
            let mut b = Vec::with_capacity(self.bh() * self.bw());
            for &r in &self.row_groups[g] {
                let base = r as usize * self.din;
                for &c in &self.col_groups[g] {
                    b.push(w_full[base + c as usize]);
                }
            }
            blocks.push(b);
        }
        Ok(blocks)
    }

    /// Scatter packed blocks back to a full (masked) matrix.
    pub fn unpack(&self, blocks: &[Vec<f32>]) -> Result<Vec<f32>> {
        if blocks.len() != self.nb {
            bail!("expected {} blocks, got {}", self.nb, blocks.len());
        }
        let mut w = vec![0f32; self.dout * self.din];
        for g in 0..self.nb {
            if blocks[g].len() != self.bh() * self.bw() {
                bail!("block {g} has wrong size");
            }
            for (i, &r) in self.row_groups[g].iter().enumerate() {
                let base = r as usize * self.din;
                for (j, &c) in self.col_groups[g].iter().enumerate() {
                    w[base + c as usize] = blocks[g][i * self.bw() + j];
                }
            }
        }
        Ok(w)
    }
}

/// A structured-pruned layer frozen for the accelerator: INT-k weight
/// codes per block, per-block weight scales, float biases (applied at the
/// end of the adder tree), and the per-block output quantizer scales.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    pub structure: BlockStructure,
    pub bits: u32,
    /// `codes[g]` — row-major `bh × bw` INT-k weight codes of block `g`.
    pub codes: Vec<Vec<i8>>,
    /// Per-block weight scale (dequant: `w = code * w_scale[g]`).
    pub w_scale: Vec<f32>,
    /// Per-block bias, `bh` entries each (packed row order).
    pub bias: Vec<Vec<f32>>,
    /// Per-block output quantizer scale (end of adder tree); `0.0`
    /// bypasses the quantizer (logit heads keep full precision).
    pub out_scale: Vec<f32>,
    pub relu: bool,
}

impl PackedLayer {
    /// Quantize a full dense float matrix into a packed layer using the
    /// given structure (compiler path for imported dense models).
    pub fn quantize_from(
        structure: BlockStructure,
        bits: u32,
        w_full: &[f32],
        bias_full: &[f32],
        out_scale: Vec<f32>,
        relu: bool,
    ) -> Result<PackedLayer> {
        if bias_full.len() != structure.dout {
            bail!("bias len {} != dout {}", bias_full.len(), structure.dout);
        }
        if out_scale.len() != structure.nb {
            bail!("out_scale len {} != nb {}", out_scale.len(), structure.nb);
        }
        let blocks = structure.pack(w_full)?;
        let mut codes = Vec::with_capacity(structure.nb);
        let mut w_scale = Vec::with_capacity(structure.nb);
        let mut bias = Vec::with_capacity(structure.nb);
        for (g, blk) in blocks.iter().enumerate() {
            let q = Quantizer::calibrate(bits, blk);
            codes.push(blk.iter().map(|&w| q.quantize(w) as i8).collect());
            w_scale.push(q.scale);
            bias.push(structure.row_groups[g].iter().map(|&r| bias_full[r as usize]).collect());
        }
        Ok(PackedLayer { structure, bits, codes, w_scale, bias, out_scale, relu })
    }

    /// Reference forward for one input vector (already in original input
    /// order): gather → per-block integer mat-vec → bias/ReLU/quant →
    /// scatter. This is the *functional* model; the cycle-accurate
    /// simulator must produce exactly these numbers.
    pub fn forward(&self, a: &[f32]) -> Result<Vec<f32>> {
        let s = &self.structure;
        if a.len() != s.din {
            bail!("input len {} != din {}", a.len(), s.din);
        }
        let (bh, bw) = (s.bh(), s.bw());
        let mut out = vec![0f32; s.dout];
        for g in 0..s.nb {
            let oq = (self.out_scale[g] > 0.0).then(|| Quantizer::new(self.bits, self.out_scale[g]));
            for i in 0..bh {
                let mut acc = 0f64;
                let row = &self.codes[g][i * bw..(i + 1) * bw];
                for (j, &c) in row.iter().enumerate() {
                    acc += c as f64 * a[s.col_groups[g][j] as usize] as f64;
                }
                let mut o = (acc as f32) * self.w_scale[g] + self.bias[g][i];
                if self.relu {
                    o = o.max(0.0);
                }
                out[s.row_groups[g][i] as usize] = match &oq {
                    Some(q) => q.fake(o),
                    None => o,
                };
            }
        }
        Ok(out)
    }

    /// Weight memory footprint of one PE's block, bits.
    pub fn weight_bits_per_block(&self) -> usize {
        self.structure.bh() * self.structure.bw() * self.bits as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn structure(dout: usize, din: usize, nb: usize, seed: u64) -> BlockStructure {
        BlockStructure::random(dout, din, nb, &mut Rng::new(seed)).unwrap()
    }

    #[test]
    fn groups_partition_indices() {
        let s = structure(24, 36, 6, 1);
        let mut rows: Vec<u32> = s.row_groups.iter().flatten().copied().collect();
        rows.sort_unstable();
        assert_eq!(rows, (0..24).collect::<Vec<u32>>());
        let mut cols: Vec<u32> = s.col_groups.iter().flatten().copied().collect();
        cols.sort_unstable();
        assert_eq!(cols, (0..36).collect::<Vec<u32>>());
    }

    #[test]
    fn mask_density_is_one_over_nb() {
        let s = structure(20, 30, 5, 2);
        let ones: usize = s.mask().iter().map(|&b| b as usize).sum();
        assert_eq!(ones, 20 * 30 / 5);
        assert!((s.density() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let s = structure(12, 20, 4, 3);
        let mut rng = Rng::new(9);
        let mask = s.mask();
        let w: Vec<f32> = mask.iter().map(|&m| if m == 1 { rng.normal() } else { 0.0 }).collect();
        let blocks = s.pack(&w).unwrap();
        let back = s.unpack(&blocks).unwrap();
        assert_eq!(back, w);
    }

    #[test]
    fn from_flat_perms_roundtrip() {
        let s = structure(15, 25, 5, 4);
        let s2 = BlockStructure::from_flat_perms(15, 25, 5, &s.row_perm(), &s.col_perm()).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn from_flat_perms_rejects_non_bijection() {
        assert!(BlockStructure::from_flat_perms(4, 4, 2, &[0, 0, 1, 2], &[0, 1, 2, 3]).is_err());
        assert!(BlockStructure::from_flat_perms(4, 4, 2, &[0, 1, 2, 9], &[0, 1, 2, 3]).is_err());
        assert!(BlockStructure::from_flat_perms(4, 4, 3, &[0, 1, 2, 3], &[0, 1, 2, 3]).is_err());
    }

    #[test]
    fn random_rejects_indivisible() {
        assert!(BlockStructure::random(10, 12, 3, &mut Rng::new(0)).is_err());
        assert!(BlockStructure::random(10, 12, 0, &mut Rng::new(0)).is_err());
    }

    #[test]
    fn packed_forward_matches_masked_dense() {
        // Fig. 1 equivalence at the rust level: packed integer forward ==
        // masked dense float forward when weights sit on the INT grid.
        let s = structure(12, 18, 3, 5);
        let mut rng = Rng::new(6);
        // weights already on an INT4 grid so quantization is exact
        let scale = 0.25f32;
        let mask = s.mask();
        let w: Vec<f32> = mask
            .iter()
            .map(|&m| if m == 1 { (rng.below(15) as i32 - 7) as f32 * scale } else { 0.0 })
            .collect();
        let bias: Vec<f32> = (0..12).map(|_| rng.normal() * 0.1).collect();
        let a: Vec<f32> = (0..18).map(|_| rng.normal()).collect();
        let out_scale = vec![0.5f32; 3];

        let packed = PackedLayer::quantize_from(s.clone(), 4, &w, &bias, out_scale.clone(), true).unwrap();
        let got = packed.forward(&a).unwrap();

        // masked dense reference
        for r in 0..12 {
            let mut acc = 0f64;
            for c in 0..18 {
                acc += (w[r * 18 + c] * mask[r * 18 + c] as f32) as f64 * a[c] as f64;
            }
            let pre = (acc as f32 + bias[r]).max(0.0);
            let g = (0..3).find(|&g| s.row_groups[g].contains(&(r as u32))).unwrap();
            let want = Quantizer::new(4, out_scale[g]).fake(pre);
            assert!((got[r] - want).abs() < 1e-4, "row {r}: {} vs {}", got[r], want);
        }
    }

    #[test]
    fn forward_rejects_wrong_input_len() {
        let s = structure(4, 6, 2, 7);
        let packed =
            PackedLayer::quantize_from(s, 4, &vec![0.0; 24], &vec![0.0; 4], vec![1.0; 2], true).unwrap();
        assert!(packed.forward(&[0.0; 5]).is_err());
    }
}
