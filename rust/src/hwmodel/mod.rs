//! Analytic area / energy / power models calibrated to the paper's 16 nm
//! TSMC silicon prototype.
//!
//! The paper's design-space-exploration figures (3, 4b, 9, 10, 11) come
//! from post-synthesis / post-P&R models of generated RTL instances. We
//! cannot tape out, so this module is the substitute substrate: component
//! models whose *constants* are calibrated against the paper's own anchor
//! points and whose *functional forms* follow standard VLSI scaling
//! (Horowitz, ISSCC'14):
//!
//! * arithmetic energy superquadratic in operand width (multipliers),
//!   linear in adder bits;
//! * SRAM access energy per bit growing with `sqrt(capacity)` (bitline
//!   length), sublinear exponent tuned to the paper's precision sweep;
//! * SRAM area linear in bits; logic area quadratic in multiplier width.
//!
//! Anchor points the unit tests pin down (paper values):
//! * Fig. 4b — PE @ 400×400 INT4: memory >50% of PE power, compute ≈25%;
//! * Fig. 9  — 10 PE chip @1 GHz: ≈440 mW, ≈6.25 mm², 16 INT4 TOPS,
//!   ≈36 TOPS/W;
//! * Fig. 10b/11b — precision sweep @400×400: memory dominates at 4 b,
//!   break-even at 8 b, compute ≈3× memory at 16 b;
//! * Fig. 10a/11a — block-size sweep: compute linear, memory quadratic;
//! * §4.1 — DRAM→SRAM ≈10× energy; near-processor SRAM a further ≈3×.

pub mod pe;
pub mod tech;

pub use pe::{PeConfig, PeEnergy, PeArea, PeMode, pe_area, pe_energy_per_cycle, adder_tree_bits};
pub use tech::Tech;

/// Chip-level design instance metrics (paper Fig. 9 table).
#[derive(Debug, Clone, PartialEq)]
pub struct ChipMetrics {
    /// Total die area, mm².
    pub area_mm2: f64,
    /// Total power at `clock_ghz`, mW.
    pub power_mw: f64,
    /// INT-normalized throughput, TOPS (paper's normalization: real
    /// multiplies + mixed-precision adder tree + quantization, all
    /// re-expressed in base-precision ops — §4.3's "1600 GOPs per PE").
    pub tops: f64,
    /// Energy efficiency, TOPS/W.
    pub tops_per_watt: f64,
    /// Total on-chip SRAM, bits.
    pub sram_bits: u64,
    /// Single-layer processing latency, cycles (block rows per PE).
    pub layer_cycles: u64,
}

/// Compute chip-level metrics for an APU instance: `n_pes` spatial PEs of
/// the given config, plus host core, routing network, and clock tree.
pub fn chip_metrics(tech: &Tech, pe_cfg: &PeConfig, n_pes: usize, clock_ghz: f64) -> ChipMetrics {
    let pe_e = pe_energy_per_cycle(tech, pe_cfg, PeMode::Spatial);
    let pe_a = pe_area(tech, pe_cfg, PeMode::Spatial);

    // Host RISC-V + L1 caches + routing matrix + clock tree: fixed blocks
    // calibrated so the Fig. 9 instance lands on the reported 440 mW /
    // 6.25 mm² (the paper's power number "includes the clock tree and the
    // RISC-V").
    let host_pj_per_cycle = tech.host_pj_per_cycle;
    let routing_pj = tech.mux_pj_per_bit * (pe_cfg.bits as f64) * n_pes as f64;
    let clock_pj = tech.clock_tree_pj_per_pe * n_pes as f64;

    let total_pj_per_cycle = pe_e.total() * n_pes as f64 + host_pj_per_cycle + routing_pj + clock_pj;
    let power_mw = total_pj_per_cycle * clock_ghz; // pJ/cycle × Gcycle/s = mW

    let area_mm2 = pe_a.total() * n_pes as f64 + tech.host_area_mm2 + tech.padring_area_mm2;

    // Paper §4.3 ops accounting: per cycle per PE, `bw` real multiplies
    // plus the mixed-precision adder tree normalized to base precision
    // plus quantize/ReLU — totalling 4·bw base-precision ops (400-wide PE
    // → 1600 GOPS at 1 GHz).
    let ops_per_cycle_per_pe = 4.0 * pe_cfg.block_w as f64;
    let tops = ops_per_cycle_per_pe * n_pes as f64 * clock_ghz / 1000.0;
    let tops_per_watt = tops / (power_mw / 1000.0);

    let sram_bits = (pe_cfg.weight_sram_bits()
        + pe_cfg.out_sram_bits()
        + pe_cfg.select_sram_bits(n_pes)) as u64
        * n_pes as u64;

    ChipMetrics {
        area_mm2,
        power_mw,
        tops,
        tops_per_watt,
        sram_bits,
        layer_cycles: pe_cfg.block_h as u64,
    }
}

/// Energy ratio helpers used by the §4.1 claims and baseline models.
pub fn dram_vs_sram_ratio(tech: &Tech) -> f64 {
    tech.dram_pj_per_bit / tech.sram_pj_per_bit(1 << 23)
}

/// Near-processor (in-PE, small) vs far (large shared) SRAM energy ratio.
pub fn near_vs_far_sram_ratio(tech: &Tech) -> f64 {
    tech.sram_pj_per_bit(1 << 23) / tech.sram_pj_per_bit(640 * 1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig9_cfg() -> PeConfig {
        PeConfig { block_h: 400, block_w: 400, bits: 4 }
    }

    #[test]
    fn fig9_chip_anchors() {
        let t = Tech::tsmc16();
        let m = chip_metrics(&t, &fig9_cfg(), 10, 1.0);
        // Paper: 440 mW, 6.25 mm², 16 TOPS, 36 TOPS/W, 8 Mb SRAM, 400-cycle layer.
        assert!((m.power_mw - 440.0).abs() < 60.0, "power {}", m.power_mw);
        assert!((m.area_mm2 - 6.25).abs() < 0.8, "area {}", m.area_mm2);
        assert!((m.tops - 16.0).abs() < 0.1, "tops {}", m.tops);
        assert!((m.tops_per_watt - 36.4).abs() < 6.0, "tops/w {}", m.tops_per_watt);
        assert_eq!(m.layer_cycles, 400);
        // 10 PEs × 400×400×4b weights = 6.4 Mb; out/select push toward 8 Mb.
        assert!(m.sram_bits > 6_400_000 && m.sram_bits < 9_000_000, "sram {}", m.sram_bits);
    }

    #[test]
    fn fig4b_power_shares() {
        let t = Tech::tsmc16();
        let e = pe_energy_per_cycle(&t, &fig9_cfg(), PeMode::Spatial);
        let mem_share = e.memory() / e.total();
        let compute_share = e.compute() / e.total();
        assert!(mem_share > 0.45 && mem_share < 0.65, "mem share {mem_share}");
        assert!(compute_share > 0.18 && compute_share < 0.32, "compute share {compute_share}");
    }

    #[test]
    fn fig11b_precision_break_even_at_8bit() {
        let t = Tech::tsmc16();
        let ratio = |bits: u32| {
            let cfg = PeConfig { block_h: 400, block_w: 400, bits };
            let e = pe_energy_per_cycle(&t, &cfg, PeMode::Spatial);
            e.compute() / e.memory()
        };
        assert!(ratio(4) < 0.6, "4b compute/mem {}", ratio(4)); // memory dominates
        assert!((ratio(8) - 1.0).abs() < 0.25, "8b compute/mem {}", ratio(8)); // break-even
        assert!(ratio(16) > 2.0, "16b compute/mem {}", ratio(16)); // compute ≈3×
    }

    #[test]
    fn fig10a_scaling_shapes() {
        // Compute area/energy linear in block dim; memory quadratic.
        let t = Tech::tsmc16();
        let metric = |s: usize| {
            let cfg = PeConfig { block_h: s, block_w: s, bits: 4 };
            let e = pe_energy_per_cycle(&t, &cfg, PeMode::Spatial);
            let a = pe_area(&t, &cfg, PeMode::Spatial);
            (e.compute(), a.memory())
        };
        let (c1, m1) = metric(256);
        let (c2, m2) = metric(1024);
        let compute_growth = c2 / c1; // expect ~4 (linear in dim, 4× dim)
        let mem_growth = m2 / m1; // expect ~16 (quadratic)
        assert!(compute_growth > 3.0 && compute_growth < 6.5, "compute growth {compute_growth}");
        assert!(mem_growth > 12.0 && mem_growth < 20.0, "mem growth {mem_growth}");
    }

    #[test]
    fn memory_hierarchy_ratios() {
        let t = Tech::tsmc16();
        let dram = dram_vs_sram_ratio(&t);
        assert!(dram > 7.0 && dram < 14.0, "dram/sram {dram}"); // paper: ~10×
        let near = near_vs_far_sram_ratio(&t);
        assert!(near > 2.0 && near < 4.5, "far/near {near}"); // paper: ~3×
    }

    #[test]
    fn more_pes_more_tops_same_efficiency_order() {
        let t = Tech::tsmc16();
        let m10 = chip_metrics(&t, &fig9_cfg(), 10, 1.0);
        let m20 = chip_metrics(&t, &fig9_cfg(), 20, 1.0);
        assert!((m20.tops / m10.tops - 2.0).abs() < 1e-9);
        // efficiency improves slightly (fixed host amortized)
        assert!(m20.tops_per_watt > m10.tops_per_watt);
    }
}
