//! Technology-node constants, calibrated to the paper's 16 nm TSMC numbers.
//!
//! Functional forms follow standard scaling (Horowitz ISSCC'14); the
//! constants below were fit to the paper's anchor points:
//!
//! * PE @400×400 INT4 weight-SRAM row read ≈18 pJ so the Fig. 4b memory
//!   share lands >50% and the Fig. 9 chip at ≈440 mW;
//! * multiplier energy `∝ bits^2.6` and SRAM per-bit energy
//!   `∝ capacity^0.42` so the Fig. 11b precision sweep breaks even at
//!   8 bits with compute >2× memory at 16 bits (paper reads ≈3×; the
//!   paper's own curve implies a superquadratic multiplier exponent);
//! * DRAM access = 10× big-SRAM access per bit, and big-SRAM = ≈3× the
//!   in-PE SRAM (the §4.1 "10×" and "3×" energy-saving steps).

/// Per-node constants. All energies in pJ, areas in mm² unless noted.
#[derive(Debug, Clone)]
pub struct Tech {
    pub name: &'static str,
    /// SRAM read energy scale: pJ per bit per `capacity_bits^cap_exp`.
    pub sram_pj_coeff: f64,
    /// Capacity exponent for SRAM per-bit access energy (bitline scaling).
    pub sram_cap_exp: f64,
    /// SRAM write multiplier relative to read.
    pub sram_write_factor: f64,
    /// Multiplier energy: pJ per multiplier per `bits^mult_exp`.
    pub mult_pj_coeff: f64,
    pub mult_exp: f64,
    /// Adder energy per adder-bit, pJ.
    pub add_pj_per_bit: f64,
    /// Latch/FF read energy per bit, pJ.
    pub latch_pj_per_bit: f64,
    /// Register-file access (read or write) per bit, pJ (temporal mode).
    pub regfile_pj_per_bit: f64,
    /// Crossbar broadcast driver energy per PE per cycle, pJ.
    pub broadcast_pj: f64,
    /// Mux network energy per routed bit, pJ.
    pub mux_pj_per_bit: f64,
    /// Control/sequencing overhead as a fraction of PE subtotal.
    pub control_overhead: f64,
    /// DRAM access energy per bit, pJ (off-chip; baselines only).
    pub dram_pj_per_bit: f64,
    /// Host core (RISC-V + L1) energy per cycle, pJ.
    pub host_pj_per_cycle: f64,
    /// Clock-tree energy per PE per cycle, pJ.
    pub clock_tree_pj_per_pe: f64,
    /// Host ops (non-MAC: pooling, fold-adds) energy per op, pJ.
    pub host_pj_per_op: f64,

    /// SRAM area per bit (incl. periphery overhead), mm².
    pub sram_mm2_per_bit: f64,
    /// Multiplier area: mm² per `bits²`.
    pub mult_mm2_per_bit2: f64,
    /// Adder area per adder-bit, mm².
    pub add_mm2_per_bit: f64,
    /// Register-file area per bit, mm².
    pub regfile_mm2_per_bit: f64,
    /// PE control/wiring area overhead fraction.
    pub area_overhead: f64,
    /// Host core + caches area, mm².
    pub host_area_mm2: f64,
    /// Pad ring, clock spine, filler — fixed die overhead, mm².
    pub padring_area_mm2: f64,
}

impl Tech {
    /// The paper's node: 16 nm TSMC at 0.72 V, 1 GHz signoff.
    pub fn tsmc16() -> Tech {
        Tech {
            name: "tsmc16",
            sram_pj_coeff: 4.095e-5,
            sram_cap_exp: 0.42,
            sram_write_factor: 1.8,
            mult_pj_coeff: 4.926e-4,
            mult_exp: 2.6,
            add_pj_per_bit: 0.0011,
            latch_pj_per_bit: 0.0002,
            regfile_pj_per_bit: 0.0008,
            broadcast_pj: 1.0,
            mux_pj_per_bit: 0.15,
            control_overhead: 0.10,
            dram_pj_per_bit: 0.331,
            host_pj_per_cycle: 90.0,
            clock_tree_pj_per_pe: 2.5,
            host_pj_per_op: 1.2,

            sram_mm2_per_bit: 1.1e-7,
            mult_mm2_per_bit2: 3.125e-6 * 1e-3, // 3.125 µm²/bit² → mm²
            add_mm2_per_bit: 2.5e-6 * 1e-3,     // 2.5 µm²/bit
            regfile_mm2_per_bit: 1.5e-6 * 1e-3,
            area_overhead: 0.15,
            host_area_mm2: 2.0,
            padring_area_mm2: 3.1,
        }
    }

    /// SRAM read energy per bit for a macro of the given capacity.
    pub fn sram_pj_per_bit(&self, capacity_bits: usize) -> f64 {
        self.sram_pj_coeff * (capacity_bits.max(1) as f64).powf(self.sram_cap_exp)
    }

    /// Energy of reading `bits_read` bits from a macro of `capacity_bits`.
    pub fn sram_read_pj(&self, bits_read: usize, capacity_bits: usize) -> f64 {
        bits_read as f64 * self.sram_pj_per_bit(capacity_bits)
    }

    /// Energy of writing `bits` bits into a macro of `capacity_bits`.
    pub fn sram_write_pj(&self, bits: usize, capacity_bits: usize) -> f64 {
        self.sram_write_factor * self.sram_read_pj(bits, capacity_bits)
    }

    /// One `bits × bits` multiply, pJ.
    pub fn mult_pj(&self, bits: u32) -> f64 {
        self.mult_pj_coeff * (bits as f64).powf(self.mult_exp)
    }

    /// DRAM transfer energy for `bits` bits, pJ.
    pub fn dram_pj(&self, bits: usize) -> f64 {
        self.dram_pj_per_bit * bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_energy_grows_with_capacity() {
        let t = Tech::tsmc16();
        let small = t.sram_pj_per_bit(64 * 1024);
        let big = t.sram_pj_per_bit(8 * 1024 * 1024);
        assert!(big > small * 2.0, "capacity scaling too flat: {small} vs {big}");
    }

    #[test]
    fn mult_energy_superquadratic() {
        let t = Tech::tsmc16();
        let r = t.mult_pj(16) / t.mult_pj(8);
        assert!(r > 4.0, "8→16 bit mult growth {r} should exceed quadratic (4×)");
        assert!(t.mult_pj(4) > 0.0);
    }

    #[test]
    fn write_costs_more_than_read() {
        let t = Tech::tsmc16();
        assert!(t.sram_write_pj(100, 1 << 16) > t.sram_read_pj(100, 1 << 16));
    }

    #[test]
    fn fig4b_weight_row_read_anchor() {
        // 400×400×4b PE: one 1600-bit row from the 640 kb macro ≈ 18 pJ.
        let t = Tech::tsmc16();
        let pj = t.sram_read_pj(1600, 640_000);
        assert!((pj - 18.0).abs() < 2.0, "row read {pj} pJ");
    }
}
