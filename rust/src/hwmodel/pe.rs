//! Processing-element area/energy models for both execution modes
//! (paper §3.1.1, Figs. 3 and 4).
//!
//! **Spatial** (the paper's choice): per cycle one output activation is
//! produced — `block_w` multipliers feed a mixed-precision reduction adder
//! tree, then ReLU and the quantizer; one weight-SRAM row is read per
//! cycle; no partial-sum register file exists.
//!
//! **Temporal** (the conventional alternative): per cycle one *input*
//! activation is broadcast — `block_h` multipliers each update a partial
//! sum held in a register file at full accumulator width; outputs all
//! complete on the layer's last cycle.

use super::tech::Tech;

/// Geometry + precision of one PE (one dense block of the pruned layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeConfig {
    /// Block rows = output activations per block.
    pub block_h: usize,
    /// Block cols = input activations per block = multipliers (spatial).
    pub block_w: usize,
    /// Weight/activation precision, bits.
    pub bits: u32,
}

impl PeConfig {
    pub fn weight_sram_bits(&self) -> usize {
        self.block_h * self.block_w * self.bits as usize
    }

    /// Output-activation SRAM: holds this block's outputs (they become the
    /// next layer's permuted inputs — paper Fig. 5).
    pub fn out_sram_bits(&self) -> usize {
        self.block_h * self.bits as usize
    }

    /// Select SRAM: static-schedule mux selects, one per routed cycle.
    pub fn select_sram_bits(&self, n_pes: usize) -> usize {
        let sel_width = (n_pes.max(2) as f64).log2().ceil() as usize;
        self.block_w * sel_width
    }

    /// Input activation latch, bits.
    pub fn input_latch_bits(&self) -> usize {
        self.block_w * self.bits as usize
    }

    /// Accumulator width for an exact dot product: `2·bits + log2(block_w)`.
    pub fn acc_bits(&self) -> u32 {
        2 * self.bits + (self.block_w.max(2) as f64).log2().ceil() as u32
    }
}

/// Execution mode of the MAC datapath (paper §3.1.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeMode {
    Spatial,
    Temporal,
}

/// Total adder-bit count of the reduction tree: stage `s` has
/// `ceil(w / 2^s)` adders of width `bits + s` (precision grows one bit per
/// stage — the paper's "adders increasing in precision", §3.1.1).
pub fn adder_tree_bits(block_w: usize, bits: u32) -> usize {
    let mut total = 0usize;
    let mut n = block_w;
    let mut stage = 1u32;
    while n > 1 {
        n = n.div_ceil(2);
        total += n * (bits + stage) as usize;
        stage += 1;
    }
    total
}

/// Per-cycle PE energy, split by component (pJ). Fig. 4b's pie chart.
#[derive(Debug, Clone, PartialEq)]
pub struct PeEnergy {
    pub weight_sram_pj: f64,
    pub out_sram_pj: f64,
    pub select_sram_pj: f64,
    pub input_latch_pj: f64,
    pub multipliers_pj: f64,
    pub adders_pj: f64,
    pub relu_quant_pj: f64,
    pub regfile_pj: f64,
    pub broadcast_pj: f64,
    pub control_pj: f64,
}

impl PeEnergy {
    pub fn memory(&self) -> f64 {
        self.weight_sram_pj + self.out_sram_pj + self.select_sram_pj
    }

    pub fn compute(&self) -> f64 {
        self.multipliers_pj + self.adders_pj + self.relu_quant_pj
    }

    pub fn other(&self) -> f64 {
        self.input_latch_pj + self.regfile_pj + self.broadcast_pj + self.control_pj
    }

    pub fn total(&self) -> f64 {
        self.memory() + self.compute() + self.other()
    }
}

/// Per-cycle PE energy for the given mode.
pub fn pe_energy_per_cycle(tech: &Tech, cfg: &PeConfig, mode: PeMode) -> PeEnergy {
    let b = cfg.bits;
    let wcap = cfg.weight_sram_bits();
    let (weight_bits_read, mult_count, adders_pj, regfile_pj) = match mode {
        PeMode::Spatial => {
            // One weight row, block_w multipliers, the reduction tree.
            let row = cfg.block_w * b as usize;
            let tree = adder_tree_bits(cfg.block_w, b);
            (row, cfg.block_w, tree as f64 * tech.add_pj_per_bit, 0.0)
        }
        PeMode::Temporal => {
            // One weight column, block_h multipliers, block_h full-width
            // accumulations + partial-sum register file (read + write).
            let col = cfg.block_h * b as usize;
            let acc = cfg.acc_bits() as usize;
            let adds = cfg.block_h * acc;
            let rf = 2.0 * (cfg.block_h * acc) as f64 * tech.regfile_pj_per_bit;
            (col, cfg.block_h, adds as f64 * tech.add_pj_per_bit, rf)
        }
    };

    let weight_sram_pj = tech.sram_read_pj(weight_bits_read, wcap);
    // One output activation (spatial) or amortized writeback (temporal).
    let out_sram_pj = tech.sram_write_pj(b as usize, cfg.out_sram_bits().max(1));
    let select_sram_pj = tech.sram_read_pj(4, cfg.select_sram_bits(16).max(1));
    let input_latch_pj = cfg.input_latch_bits() as f64 * tech.latch_pj_per_bit;
    let multipliers_pj = mult_count as f64 * tech.mult_pj(b);
    // ReLU compare + quantizer shift/round at accumulator width.
    let relu_quant_pj = 2.0 * cfg.acc_bits() as f64 * tech.add_pj_per_bit;
    let broadcast_pj = tech.broadcast_pj;

    let subtotal = weight_sram_pj
        + out_sram_pj
        + select_sram_pj
        + input_latch_pj
        + multipliers_pj
        + adders_pj
        + relu_quant_pj
        + regfile_pj
        + broadcast_pj;
    let control_pj = tech.control_overhead * subtotal;

    PeEnergy {
        weight_sram_pj,
        out_sram_pj,
        select_sram_pj,
        input_latch_pj,
        multipliers_pj,
        adders_pj,
        relu_quant_pj,
        regfile_pj,
        broadcast_pj,
        control_pj,
    }
}

/// Energy to process one full block (all outputs) in the given mode, pJ.
/// Spatial takes `block_h` cycles; temporal takes `block_w` cycles.
pub fn pe_energy_per_block(tech: &Tech, cfg: &PeConfig, mode: PeMode) -> f64 {
    let per_cycle = pe_energy_per_cycle(tech, cfg, mode).total();
    let cycles = match mode {
        PeMode::Spatial => cfg.block_h,
        PeMode::Temporal => cfg.block_w,
    };
    per_cycle * cycles as f64
}

/// PE area by component, mm². Fig. 3 (right) / Fig. 10.
#[derive(Debug, Clone, PartialEq)]
pub struct PeArea {
    pub weight_sram_mm2: f64,
    pub io_sram_mm2: f64,
    pub multipliers_mm2: f64,
    pub adders_mm2: f64,
    pub regfile_mm2: f64,
    pub overhead_mm2: f64,
}

impl PeArea {
    pub fn memory(&self) -> f64 {
        self.weight_sram_mm2 + self.io_sram_mm2
    }

    pub fn compute(&self) -> f64 {
        self.multipliers_mm2 + self.adders_mm2
    }

    pub fn total(&self) -> f64 {
        self.memory() + self.compute() + self.regfile_mm2 + self.overhead_mm2
    }
}

/// PE area for the given mode.
pub fn pe_area(tech: &Tech, cfg: &PeConfig, mode: PeMode) -> PeArea {
    let b = cfg.bits;
    let weight_sram_mm2 = cfg.weight_sram_bits() as f64 * tech.sram_mm2_per_bit;
    let io_bits = cfg.out_sram_bits() + cfg.select_sram_bits(16) + cfg.input_latch_bits();
    let io_sram_mm2 = io_bits as f64 * tech.sram_mm2_per_bit;

    let (mult_count, adder_bits, regfile_bits) = match mode {
        PeMode::Spatial => (cfg.block_w, adder_tree_bits(cfg.block_w, b), 0),
        PeMode::Temporal => {
            let acc = cfg.acc_bits() as usize;
            (cfg.block_h, cfg.block_h * acc, cfg.block_h * acc)
        }
    };
    let multipliers_mm2 = mult_count as f64 * (b as f64).powi(2) * tech.mult_mm2_per_bit2;
    let adders_mm2 = adder_bits as f64 * tech.add_mm2_per_bit;
    let regfile_mm2 = regfile_bits as f64 * tech.regfile_mm2_per_bit;
    let overhead_mm2 =
        tech.area_overhead * (weight_sram_mm2 + io_sram_mm2 + multipliers_mm2 + adders_mm2 + regfile_mm2);

    PeArea { weight_sram_mm2, io_sram_mm2, multipliers_mm2, adders_mm2, regfile_mm2, overhead_mm2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PeConfig {
        PeConfig { block_h: 400, block_w: 400, bits: 4 }
    }

    #[test]
    fn adder_tree_has_nine_stages_at_400() {
        // Paper §3.1.1: 400 multipliers feed a 9-stage adder tree.
        let mut n = 400usize;
        let mut stages = 0;
        while n > 1 {
            n = n.div_ceil(2);
            stages += 1;
        }
        assert_eq!(stages, 9);
        let bits = adder_tree_bits(400, 4);
        // 402 adders, widths 5..13.
        assert!(bits > 2000 && bits < 2600, "tree bits {bits}");
    }

    #[test]
    fn sram_sizes() {
        let c = cfg();
        assert_eq!(c.weight_sram_bits(), 640_000);
        assert_eq!(c.out_sram_bits(), 1600);
        assert_eq!(c.input_latch_bits(), 1600);
        assert_eq!(c.select_sram_bits(16), 1600);
        assert_eq!(c.acc_bits(), 17);
    }

    #[test]
    fn fig3_spatial_beats_temporal_on_energy_and_area() {
        // Paper Fig. 3: same weight+multiplier cost, spatial saves the
        // adder precision and eliminates the partial-sum register file.
        let t = Tech::tsmc16();
        let sp_e = pe_energy_per_block(&t, &cfg(), PeMode::Spatial);
        let tp_e = pe_energy_per_block(&t, &cfg(), PeMode::Temporal);
        assert!(sp_e < tp_e, "spatial {sp_e} should beat temporal {tp_e}");

        let sp = pe_energy_per_cycle(&t, &cfg(), PeMode::Spatial);
        let tp = pe_energy_per_cycle(&t, &cfg(), PeMode::Temporal);
        // identical components (square block): weight read + multipliers
        assert!((sp.weight_sram_pj - tp.weight_sram_pj).abs() < 1e-9);
        assert!((sp.multipliers_pj - tp.multipliers_pj).abs() < 1e-9);
        // savings live in adders + regfile
        assert!(sp.adders_pj < tp.adders_pj);
        assert_eq!(sp.regfile_pj, 0.0);
        assert!(tp.regfile_pj > 0.0);

        let sp_a = pe_area(&t, &cfg(), PeMode::Spatial);
        let tp_a = pe_area(&t, &cfg(), PeMode::Temporal);
        assert!(sp_a.total() < tp_a.total());
        assert_eq!(sp_a.regfile_mm2, 0.0);
    }

    #[test]
    fn block_energy_scales_with_rows() {
        let t = Tech::tsmc16();
        let small = PeConfig { block_h: 100, block_w: 400, bits: 4 };
        let e_small = pe_energy_per_block(&t, &small, PeMode::Spatial);
        let e_big = pe_energy_per_block(&t, &cfg(), PeMode::Spatial);
        // 4× the cycles, and each cycle reads a row from a 4× larger macro
        // (higher per-bit energy), so the ratio lands a little above 4×.
        assert!(e_big > e_small * 3.5 && e_big < e_small * 6.5);
    }

    #[test]
    fn non_square_blocks_supported() {
        let t = Tech::tsmc16();
        let c = PeConfig { block_h: 30, block_w: 80, bits: 4 };
        let e = pe_energy_per_cycle(&t, &c, PeMode::Spatial);
        assert!(e.total() > 0.0);
        assert!(pe_area(&t, &c, PeMode::Spatial).total() > 0.0);
    }
}
