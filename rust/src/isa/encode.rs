//! Binary instruction encoding — the 64-bit RoCC custom-instruction word.
//!
//! Layout (little-endian fields, LSB first):
//! ```text
//!   [7:0]   opcode
//!   [15:8]  flags / precision / host-op code
//!   [31:16] field a   (layer, pe, rows, seg …)
//!   [47:32] field b   (nb, seg …)
//!   [63:48] field c   (bh or bw packed via two words for ConfigLayer)
//! ```
//! `ConfigLayer` needs four 16-bit fields (nb, bh, bw + layer) so it is
//! encoded as a two-word pair (`OP_CFG`, `OP_CFG_EXT`); every other
//! instruction is a single word. This mirrors how RoCC splits a command
//! across `rs1`/`rs2`.

use anyhow::{bail, Result};

use super::program::{HostOpKind, Insn};

const OP_CFG: u8 = 0x01;
const OP_CFG_EXT: u8 = 0x02;
const OP_LD_W: u8 = 0x03;
const OP_LD_B: u8 = 0x04;
const OP_LD_S: u8 = 0x05;
const OP_ROUTE: u8 = 0x06;
const OP_COMPUTE: u8 = 0x07;
const OP_HOST: u8 = 0x08;
const OP_SCATTER: u8 = 0x09;
const OP_HOSTDENSE: u8 = 0x0A;
const OP_HALT: u8 = 0x0F;

fn word(op: u8, flags: u8, a: u16, b: u16, c: u16) -> u64 {
    (op as u64) | ((flags as u64) << 8) | ((a as u64) << 16) | ((b as u64) << 32) | ((c as u64) << 48)
}

fn fields(w: u64) -> (u8, u8, u16, u16, u16) {
    (w as u8, (w >> 8) as u8, (w >> 16) as u16, (w >> 32) as u16, (w >> 48) as u16)
}

/// Encode one instruction to one or two 64-bit words.
pub fn encode_insn(insn: &Insn) -> Vec<u64> {
    match *insn {
        Insn::ConfigLayer { layer, nb, bh, bw, bits, relu } => vec![
            word(OP_CFG, bits | ((relu as u8) << 7), layer, nb, bh),
            word(OP_CFG_EXT, 0, bw, 0, 0),
        ],
        Insn::LoadWeights { pe, seg } => vec![word(OP_LD_W, 0, pe, seg, 0)],
        Insn::LoadBias { pe, seg } => vec![word(OP_LD_B, 0, pe, seg, 0)],
        Insn::SetScales { pe, seg } => vec![word(OP_LD_S, 0, pe, seg, 0)],
        Insn::Route { seg, from_input } => vec![word(OP_ROUTE, from_input as u8, seg, 0, 0)],
        Insn::Compute { rows } => vec![word(OP_COMPUTE, 0, rows, 0, 0)],
        Insn::HostOp { op, seg } => vec![word(OP_HOST, op.code(), seg, 0, 0)],
        Insn::Scatter { seg, buf } => vec![word(OP_SCATTER, 0, seg, buf, 0)],
        Insn::HostDense { w_seg, b_seg, relu } => vec![word(OP_HOSTDENSE, relu as u8, w_seg, b_seg, 0)],
        Insn::Halt => vec![word(OP_HALT, 0, 0, 0, 0)],
    }
}

/// Decode an instruction starting at `words[i]`; returns the instruction
/// and the number of words consumed.
pub fn decode_insn(words: &[u64], i: usize) -> Result<(Insn, usize)> {
    let w = *words.get(i).ok_or_else(|| anyhow::anyhow!("decode past end"))?;
    let (op, flags, a, b, c) = fields(w);
    Ok(match op {
        OP_CFG => {
            let w2 = *words.get(i + 1).ok_or_else(|| anyhow::anyhow!("truncated ConfigLayer"))?;
            let (op2, _, bw, _, _) = fields(w2);
            if op2 != OP_CFG_EXT {
                bail!("ConfigLayer not followed by extension word");
            }
            (
                Insn::ConfigLayer {
                    layer: a,
                    nb: b,
                    bh: c,
                    bw,
                    bits: flags & 0x7f,
                    relu: flags & 0x80 != 0,
                },
                2,
            )
        }
        OP_CFG_EXT => bail!("orphan ConfigLayer extension word"),
        OP_LD_W => (Insn::LoadWeights { pe: a, seg: b }, 1),
        OP_LD_B => (Insn::LoadBias { pe: a, seg: b }, 1),
        OP_LD_S => (Insn::SetScales { pe: a, seg: b }, 1),
        OP_ROUTE => (Insn::Route { seg: a, from_input: flags != 0 }, 1),
        OP_COMPUTE => (Insn::Compute { rows: a }, 1),
        OP_HOST => (Insn::HostOp { op: HostOpKind::from_code(flags)?, seg: a }, 1),
        OP_SCATTER => (Insn::Scatter { seg: a, buf: b }, 1),
        OP_HOSTDENSE => (Insn::HostDense { w_seg: a, b_seg: b, relu: flags != 0 }, 1),
        OP_HALT => (Insn::Halt, 1),
        other => bail!("unknown opcode {other:#x}"),
    })
}

/// Encode a whole instruction stream.
pub fn encode_stream(insns: &[Insn]) -> Vec<u64> {
    insns.iter().flat_map(encode_insn).collect()
}

/// Decode a whole instruction stream.
pub fn decode_stream(words: &[u64]) -> Result<Vec<Insn>> {
    let mut insns = Vec::new();
    let mut i = 0;
    while i < words.len() {
        let (insn, used) = decode_insn(words, i)?;
        insns.push(insn);
        i += used;
    }
    Ok(insns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn arbitrary_insn(rng: &mut Rng) -> Insn {
        match rng.below(10) {
            0 => Insn::ConfigLayer {
                layer: rng.below(1 << 16) as u16,
                nb: rng.below(1 << 16) as u16,
                bh: rng.below(1 << 16) as u16,
                bw: rng.below(1 << 16) as u16,
                bits: [2u8, 4, 8, 16][rng.usize_below(4)],
                relu: rng.below(2) == 1,
            },
            1 => Insn::LoadWeights { pe: rng.below(1 << 16) as u16, seg: rng.below(1 << 16) as u16 },
            2 => Insn::LoadBias { pe: rng.below(1 << 16) as u16, seg: rng.below(1 << 16) as u16 },
            3 => Insn::SetScales { pe: rng.below(1 << 16) as u16, seg: rng.below(1 << 16) as u16 },
            4 => Insn::Route { seg: rng.below(1 << 16) as u16, from_input: rng.below(2) == 1 },
            5 => Insn::Compute { rows: rng.below(1 << 16) as u16 },
            6 => Insn::HostOp {
                op: HostOpKind::from_code(rng.below(5) as u8).unwrap(),
                seg: rng.below(1 << 16) as u16,
            },
            7 => Insn::Scatter { seg: rng.below(1 << 16) as u16, buf: rng.below(1 << 16) as u16 },
            8 => Insn::HostDense {
                w_seg: rng.below(1 << 16) as u16,
                b_seg: rng.below(1 << 16) as u16,
                relu: rng.below(2) == 1,
            },
            _ => Insn::Halt,
        }
    }

    #[test]
    fn roundtrip_property() {
        // 500 random instruction streams survive encode→decode untouched.
        let mut rng = Rng::new(99);
        for _ in 0..500 {
            let n = 1 + rng.usize_below(20);
            let insns: Vec<Insn> = (0..n).map(|_| arbitrary_insn(&mut rng)).collect();
            let words = encode_stream(&insns);
            let back = decode_stream(&words).unwrap();
            assert_eq!(insns, back);
        }
    }

    #[test]
    fn config_layer_uses_two_words() {
        let insn = Insn::ConfigLayer { layer: 1, nb: 10, bh: 30, bw: 80, bits: 4, relu: true };
        assert_eq!(encode_insn(&insn).len(), 2);
        assert_eq!(encode_insn(&Insn::Halt).len(), 1);
    }

    #[test]
    fn rejects_truncated_and_orphan() {
        let insn = Insn::ConfigLayer { layer: 0, nb: 1, bh: 1, bw: 1, bits: 4, relu: false };
        let words = encode_insn(&insn);
        assert!(decode_stream(&words[..1]).is_err()); // truncated
        assert!(decode_stream(&words[1..]).is_err()); // orphan ext
        assert!(decode_stream(&[0xFEu64]).is_err()); // unknown opcode
    }

    #[test]
    fn max_field_values_roundtrip() {
        let insn = Insn::ConfigLayer { layer: u16::MAX, nb: u16::MAX, bh: u16::MAX, bw: u16::MAX, bits: 16, relu: true };
        let back = decode_stream(&encode_insn(&insn)).unwrap();
        assert_eq!(vec![insn], back);
    }
}
