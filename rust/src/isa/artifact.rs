//! On-disk program artifacts: the binary container `apu compile --out`
//! writes and the fleet/engine loaders read back.
//!
//! Layout (all little-endian):
//! ```text
//!   magic   "APU2"
//!   name    u32 len + utf8 bytes
//!   din     u64
//!   dout    u64
//!   insns   u32 word count + u64 words (the RoCC encoding, `isa::encode`)
//!   data    u32 segment count, then per segment:
//!             u8 tag (0=i8, 1=f32, 2=u32, 3=routes) + u32 len + payload
//!             (routes serialize as cycle:u32 src:u16 dst:u16 act:u32 slot:u32)
//! ```
//! Loading re-validates the program, so a corrupted artifact errors
//! instead of mis-executing.
//!
//! Version history: "APU1" predates buffer-selecting scatters and the
//! runtime-operand `FoldAdd` (§4.4.3-II); its `Scatter` word had no
//! buffer field and `FoldAdd` carried a static f32 operand segment, so
//! v1 blobs cannot be reinterpreted safely. Loading one errors with an
//! explicit "unsupported artifact version" message — recompile the
//! network to regenerate the artifact.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::encode::{decode_stream, encode_stream};
use super::program::{DataSegment, Program};
use crate::sched::Assignment;

const MAGIC: &[u8; 4] = b"APU2";

/// FNV-1a 64-bit over an artifact byte image. Stable across processes
/// and platforms (the encoding is fully little-endian and deterministic),
/// so it can key process-wide caches and name on-disk plan artifacts.
pub fn fingerprint_bytes(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            bail!("artifact truncated at byte {}", self.pos);
        };
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Check an untrusted element count against the bytes actually left,
    /// so a corrupted length field errors instead of pre-allocating GBs.
    fn check_count(&self, n: usize, elem_bytes: usize) -> Result<()> {
        let need = n.checked_mul(elem_bytes);
        let left = self.buf.len() - self.pos;
        if need.map_or(true, |need| need > left) {
            bail!("artifact claims {n} elements but only {left} bytes remain");
        }
        Ok(())
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Serialize a program to the artifact byte format.
pub fn to_bytes(p: &Program) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, p.name.len() as u32);
    out.extend_from_slice(p.name.as_bytes());
    out.extend_from_slice(&(p.din as u64).to_le_bytes());
    out.extend_from_slice(&(p.dout as u64).to_le_bytes());
    let words = encode_stream(&p.insns);
    put_u32(&mut out, words.len() as u32);
    for w in &words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    put_u32(&mut out, p.data.len() as u32);
    for seg in &p.data {
        match seg {
            DataSegment::I8(v) => {
                out.push(0);
                put_u32(&mut out, v.len() as u32);
                out.extend(v.iter().map(|&b| b as u8));
            }
            DataSegment::F32(v) => {
                out.push(1);
                put_u32(&mut out, v.len() as u32);
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            DataSegment::U32(v) => {
                out.push(2);
                put_u32(&mut out, v.len() as u32);
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            DataSegment::Routes(v) => {
                out.push(3);
                put_u32(&mut out, v.len() as u32);
                for a in v {
                    out.extend_from_slice(&a.cycle.to_le_bytes());
                    out.extend_from_slice(&a.src.to_le_bytes());
                    out.extend_from_slice(&a.dst.to_le_bytes());
                    out.extend_from_slice(&a.act.to_le_bytes());
                    out.extend_from_slice(&a.dst_slot.to_le_bytes());
                }
            }
        }
    }
    out
}

/// Parse an artifact byte buffer back into a validated program.
pub fn from_bytes(buf: &[u8]) -> Result<Program> {
    let mut r = Reader { buf, pos: 0 };
    let magic = r.take(4)?;
    if magic != MAGIC {
        if magic.starts_with(b"APU") {
            bail!(
                "unsupported artifact version {} (this build reads version {}) — recompile the network",
                magic[3] as char,
                MAGIC[3] as char
            );
        }
        bail!("not an APU program artifact (bad magic)");
    }
    let name_len = r.u32()? as usize;
    let name = String::from_utf8(r.take(name_len)?.to_vec()).context("artifact name not utf8")?;
    // Bound the claimed dims before casting: a clobbered length here would
    // otherwise flow into downstream `Vec::with_capacity` calls and abort
    // the process on capacity overflow instead of returning an error.
    const MAX_DIM: u64 = 1 << 24;
    let din = r.u64()?;
    let dout = r.u64()?;
    if din > MAX_DIM || dout > MAX_DIM {
        bail!("artifact claims absurd dims din={din} dout={dout} (max {MAX_DIM})");
    }
    let (din, dout) = (din as usize, dout as usize);
    let n_words = r.u32()? as usize;
    r.check_count(n_words, 8)?;
    let mut words = Vec::with_capacity(n_words);
    for _ in 0..n_words {
        words.push(r.u64()?);
    }
    let insns = decode_stream(&words)?;
    let n_segs = r.u32()? as usize;
    r.check_count(n_segs, 5)?; // tag + len at minimum per segment
    let mut data = Vec::with_capacity(n_segs);
    for _ in 0..n_segs {
        let tag = r.u8()?;
        let len = r.u32()? as usize;
        let seg = match tag {
            0 => DataSegment::I8(r.take(len)?.iter().map(|&b| b as i8).collect()),
            1 => {
                r.check_count(len, 4)?;
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(r.f32()?);
                }
                DataSegment::F32(v)
            }
            2 => {
                r.check_count(len, 4)?;
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(r.u32()?);
                }
                DataSegment::U32(v)
            }
            3 => {
                r.check_count(len, 16)?;
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(Assignment {
                        cycle: r.u32()?,
                        src: r.u16()?,
                        dst: r.u16()?,
                        act: r.u32()?,
                        dst_slot: r.u32()?,
                    });
                }
                DataSegment::Routes(v)
            }
            other => bail!("unknown segment tag {other}"),
        };
        data.push(seg);
    }
    if r.pos != buf.len() {
        bail!("{} trailing bytes after artifact", buf.len() - r.pos);
    }
    let p = Program { insns, data, din, dout, name };
    p.validate()?;
    Ok(p)
}

impl Program {
    /// Stable content fingerprint: the FNV-1a 64-bit hash of the
    /// canonical APU2 byte encoding. Two programs share a fingerprint iff
    /// they serialize to identical artifacts (same instructions, data
    /// segments, dims, and name), which makes it a sound key for the
    /// process-wide [`crate::sim::plan`] cache and for content-addressed
    /// artifact stores.
    pub fn fingerprint(&self) -> u64 {
        fingerprint_bytes(&to_bytes(self))
    }

    /// Write this program as a binary artifact (`apu compile --out`).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, to_bytes(self)).with_context(|| format!("writing {}", path.display()))
    }

    /// Load and validate a program artifact.
    pub fn load(path: impl AsRef<Path>) -> Result<Program> {
        let path = path.as_ref();
        let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        from_bytes(&buf).with_context(|| format!("parsing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::emit::{compile_packed_layers, synthetic_packed_network};

    fn sample() -> Program {
        let layers = synthetic_packed_network(&[16, 20, 12], 4, 4, 17).unwrap();
        compile_packed_layers("artifact-test", &layers, 0.1, 4, 4).unwrap()
    }

    #[test]
    fn byte_roundtrip_is_exact() {
        let p = sample();
        let q = from_bytes(&to_bytes(&p)).unwrap();
        assert_eq!(p.name, q.name);
        assert_eq!((p.din, p.dout), (q.din, q.dout));
        assert_eq!(p.insns, q.insns);
        assert_eq!(p.data, q.data);
    }

    #[test]
    fn rejects_corruption() {
        let p = sample();
        let mut bytes = to_bytes(&p);
        assert!(from_bytes(&bytes[..10]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(from_bytes(&bytes).is_err()); // bad magic
        assert!(from_bytes(&[]).is_err());
    }

    #[test]
    fn rejects_old_artifact_version_with_clear_error() {
        let p = sample();
        let mut bytes = to_bytes(&p);
        assert_eq!(&bytes[..4], b"APU2");
        bytes[..4].copy_from_slice(b"APU1");
        let err = from_bytes(&bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unsupported artifact version 1"), "{msg}");
        // a future version is refused the same way
        bytes[..4].copy_from_slice(b"APU9");
        let msg = format!("{:#}", from_bytes(&bytes).unwrap_err());
        assert!(msg.contains("unsupported artifact version 9"), "{msg}");
    }

    #[test]
    fn rejects_absurd_length_fields_without_allocating() {
        let p = sample();
        let mut bytes = to_bytes(&p);
        // clobber the instruction word count (magic + name + din + dout)
        let off = 4 + 4 + p.name.len() + 16;
        bytes[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_at_every_length_errors_cleanly() {
        let bytes = to_bytes(&sample());
        for k in 0..bytes.len() {
            let prefix = bytes[..k].to_vec();
            let got = std::panic::catch_unwind(move || from_bytes(&prefix).map(|_| ()));
            match got {
                Ok(parsed) => assert!(parsed.is_err(), "prefix of {k} bytes parsed as valid"),
                Err(_) => panic!("from_bytes panicked on a {k}-byte prefix"),
            }
        }
    }

    #[test]
    fn byte_corruption_never_panics() {
        let bytes = to_bytes(&sample());
        let mut rng = crate::util::rng::Rng::new(0xbad5eed);
        for case in 0..2000usize {
            let mut blob = bytes.clone();
            for _ in 0..1 + (case % 4) {
                let at = rng.usize_below(blob.len());
                blob[at] = rng.next_u64() as u8;
            }
            // Either a clean error or (rarely) a still-valid program is
            // fine; aborting the loader is not.
            let got = std::panic::catch_unwind(move || from_bytes(&blob).map(|_| ()));
            assert!(got.is_ok(), "from_bytes panicked on corrupted blob (case {case})");
        }
    }

    #[test]
    fn absurd_dims_error_instead_of_poisoning_downstream() {
        let p = sample();
        let mut bytes = to_bytes(&p);
        // din sits right after magic + name (u32 len + utf8).
        let off = 4 + 4 + p.name.len();
        bytes[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let msg = format!("{:#}", from_bytes(&bytes).unwrap_err());
        assert!(msg.contains("absurd dims"), "{msg}");
    }

    #[test]
    fn file_roundtrip() {
        let p = sample();
        let path = std::env::temp_dir().join(format!("apu-artifact-{}.bin", std::process::id()));
        p.save(&path).unwrap();
        let q = Program::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(p.insns, q.insns);
        assert_eq!(p.data, q.data);
    }
}
