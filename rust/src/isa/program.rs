//! Instruction and program containers.

use anyhow::{bail, Result};

use crate::sched::Assignment;

/// Non-MAC operations executed on the host RISC-V core (paper §4.4.3:
/// pooling "and other operations that do NOT consist of multiplication
/// and addition" run on the core).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostOpKind {
    /// Elementwise ReLU over a host buffer.
    Relu,
    /// 2D max-pool with square window (encoded in `arg`).
    MaxPool,
    /// Fold a named *runtime* partial-sum buffer into the activation
    /// stream (§4.4.3-II): `acts[i] += buf[src][i]`, then the buffer is
    /// freed. The params segment carries `[src_buf]` — the buffer id a
    /// tiled layer's wave scatters (`Scatter { buf, .. }`) filled this
    /// run. The operand data is produced at runtime by the PE tiles;
    /// only the buffer *selection* is compile-time.
    FoldAdd,
    /// Quantize a host buffer to the layer grid (scale from segment).
    Quantize,
    /// Copy/permute a host buffer (activation reordering at boundaries);
    /// a negative index gathers an implicit zero — the compiler uses this
    /// to materialize zero-padded convolution input planes.
    Gather,
}

impl HostOpKind {
    pub fn code(self) -> u8 {
        match self {
            HostOpKind::Relu => 0,
            HostOpKind::MaxPool => 1,
            HostOpKind::FoldAdd => 2,
            HostOpKind::Quantize => 3,
            HostOpKind::Gather => 4,
        }
    }

    pub fn from_code(c: u8) -> Result<HostOpKind> {
        Ok(match c {
            0 => HostOpKind::Relu,
            1 => HostOpKind::MaxPool,
            2 => HostOpKind::FoldAdd,
            3 => HostOpKind::Quantize,
            4 => HostOpKind::Gather,
            _ => bail!("bad host-op code {c}"),
        })
    }
}

/// One APU instruction (the RoCC custom-instruction trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Insn {
    /// Configure the active layer geometry: `nb` blocks of `bh × bw` at
    /// `bits` precision, ReLU on/off.
    ConfigLayer { layer: u16, nb: u16, bh: u16, bw: u16, bits: u8, relu: bool },
    /// Point PE `pe`'s weight SRAM at data segment `seg` (i8 codes).
    LoadWeights { pe: u16, seg: u16 },
    /// Point PE `pe`'s bias store at data segment `seg` (f32).
    LoadBias { pe: u16, seg: u16 },
    /// Per-PE dequant scales: weight scale and output quantizer scale.
    SetScales { pe: u16, seg: u16 },
    /// Run the routing phase using the static schedule in segment `seg`
    /// (sources = `src` kind: 0 input stream, 1 previous layer outputs).
    Route { seg: u16, from_input: bool },
    /// Run the MAC phase of the configured layer (`rows` output rows/PE).
    Compute { rows: u16 },
    /// Host-core op over host buffer(s); `seg` carries op parameters.
    HostOp { op: HostOpKind, seg: u16 },
    /// Small dense (unstructured) FC executed on the host core — the
    /// paper keeps layers too small/irregular for the PE array on the
    /// RISC-V (classifier heads). Weights/bias are f32 segments.
    HostDense { w_seg: u16, b_seg: u16, relu: bool },
    /// Copy PE output SRAMs to a host output buffer (layer scatter),
    /// using the row permutation in segment `seg`. `buf = 0` targets the
    /// layer's pending output buffer (committed when the layer ends);
    /// `buf >= 1` targets the named partial-sum buffer a later `FoldAdd`
    /// host op folds into the stream (§4.4.3-II column tiles).
    Scatter { seg: u16, buf: u16 },
    /// End of program.
    Halt,
}

/// Typed data segments the host loads for the accelerator.
#[derive(Debug, Clone, PartialEq)]
pub enum DataSegment {
    I8(Vec<i8>),
    F32(Vec<f32>),
    U32(Vec<u32>),
    /// A static routing schedule (assignment list).
    Routes(Vec<Assignment>),
}

impl DataSegment {
    pub fn kind(&self) -> &'static str {
        match self {
            DataSegment::I8(_) => "i8",
            DataSegment::F32(_) => "f32",
            DataSegment::U32(_) => "u32",
            DataSegment::Routes(_) => "routes",
        }
    }

    pub fn len(&self) -> usize {
        match self {
            DataSegment::I8(v) => v.len(),
            DataSegment::F32(v) => v.len(),
            DataSegment::U32(v) => v.len(),
            DataSegment::Routes(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            DataSegment::I8(v) => Ok(v),
            _ => bail!("segment is {} not i8", self.kind()),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            DataSegment::F32(v) => Ok(v),
            _ => bail!("segment is {} not f32", self.kind()),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            DataSegment::U32(v) => Ok(v),
            _ => bail!("segment is {} not u32", self.kind()),
        }
    }

    pub fn as_routes(&self) -> Result<&[Assignment]> {
        match self {
            DataSegment::Routes(v) => Ok(v),
            _ => bail!("segment is {} not routes", self.kind()),
        }
    }
}

/// A complete APU program: instruction stream + data segments + metadata.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub insns: Vec<Insn>,
    pub data: Vec<DataSegment>,
    /// Network input/output dimensions (host buffer sizes).
    pub din: usize,
    pub dout: usize,
    /// Human-readable provenance (model name).
    pub name: String,
}

impl Program {
    pub fn push_data(&mut self, seg: DataSegment) -> u16 {
        self.data.push(seg);
        (self.data.len() - 1) as u16
    }

    pub fn segment(&self, seg: u16) -> Result<&DataSegment> {
        self.data.get(seg as usize).ok_or_else(|| anyhow::anyhow!("segment {seg} out of range"))
    }

    /// Static validation: segment references in range and correctly typed,
    /// Halt-terminated, layer configured before compute.
    pub fn validate(&self) -> Result<()> {
        if self.insns.last() != Some(&Insn::Halt) {
            bail!("program must end with Halt");
        }
        let mut configured = false;
        for (i, insn) in self.insns.iter().enumerate() {
            let check = |seg: u16, want: &str| -> Result<()> {
                let s = self.segment(seg)?;
                if s.kind() != want {
                    bail!("insn {i}: segment {seg} is {} but {want} required", s.kind());
                }
                Ok(())
            };
            match insn {
                Insn::ConfigLayer { nb, bh, bw, bits, .. } => {
                    if *nb == 0 || *bh == 0 || *bw == 0 {
                        bail!("insn {i}: degenerate layer config");
                    }
                    if ![2, 4, 8, 16].contains(bits) {
                        bail!("insn {i}: unsupported precision {bits}");
                    }
                    configured = true;
                }
                Insn::LoadWeights { seg, .. } => check(*seg, "i8")?,
                Insn::LoadBias { seg, .. } => check(*seg, "f32")?,
                Insn::SetScales { seg, .. } => check(*seg, "f32")?,
                Insn::Route { seg, .. } => check(*seg, "routes")?,
                Insn::Compute { rows } => {
                    if !configured {
                        bail!("insn {i}: Compute before ConfigLayer");
                    }
                    if *rows == 0 {
                        bail!("insn {i}: zero-row compute");
                    }
                }
                Insn::HostOp { op, seg } => {
                    check(*seg, "f32")?;
                    if *op == HostOpKind::FoldAdd && self.segment(*seg)?.len() != 1 {
                        bail!(
                            "insn {i}: FoldAdd params must be [src_buf], got {} values",
                            self.segment(*seg)?.len()
                        );
                    }
                }
                Insn::HostDense { w_seg, b_seg, .. } => {
                    check(*w_seg, "f32")?;
                    check(*b_seg, "f32")?;
                }
                Insn::Scatter { seg, .. } => check(*seg, "u32")?,
                Insn::Halt => {}
            }
        }
        Ok(())
    }

    /// Assembly text (one insn per line) — `apu compile --emit-asm`.
    pub fn disassemble(&self) -> String {
        let mut s = String::new();
        for insn in &self.insns {
            s.push_str(&match insn {
                Insn::ConfigLayer { layer, nb, bh, bw, bits, relu } => {
                    format!("cfg.layer l={layer} nb={nb} bh={bh} bw={bw} bits={bits} relu={}", *relu as u8)
                }
                Insn::LoadWeights { pe, seg } => format!("ld.w pe={pe} seg={seg}"),
                Insn::LoadBias { pe, seg } => format!("ld.b pe={pe} seg={seg}"),
                Insn::SetScales { pe, seg } => format!("ld.s pe={pe} seg={seg}"),
                Insn::Route { seg, from_input } => format!("route seg={seg} in={}", *from_input as u8),
                Insn::Compute { rows } => format!("compute rows={rows}"),
                Insn::HostOp { op, seg } => format!("host op={} seg={seg}", op.code()),
                Insn::HostDense { w_seg, b_seg, relu } => {
                    format!("host.dense w={w_seg} b={b_seg} relu={}", *relu as u8)
                }
                Insn::Scatter { seg, buf } => format!("scatter seg={seg} buf={buf}"),
                Insn::Halt => "halt".to_string(),
            });
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Program {
        let mut p = Program { name: "t".into(), din: 8, dout: 4, ..Default::default() };
        let w = p.push_data(DataSegment::I8(vec![1, -2, 3, 4]));
        let b = p.push_data(DataSegment::F32(vec![0.1, 0.2]));
        let r = p.push_data(DataSegment::Routes(vec![]));
        let perm = p.push_data(DataSegment::U32(vec![0, 1, 2, 3]));
        p.insns = vec![
            Insn::ConfigLayer { layer: 0, nb: 2, bh: 2, bw: 2, bits: 4, relu: true },
            Insn::LoadWeights { pe: 0, seg: w },
            Insn::LoadBias { pe: 0, seg: b },
            Insn::SetScales { pe: 0, seg: b },
            Insn::Route { seg: r, from_input: true },
            Insn::Compute { rows: 2 },
            Insn::Scatter { seg: perm, buf: 0 },
            Insn::Halt,
        ];
        p
    }

    #[test]
    fn validates_good_program() {
        sample().validate().unwrap();
    }

    #[test]
    fn rejects_missing_halt() {
        let mut p = sample();
        p.insns.pop();
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_wrong_segment_type() {
        let mut p = sample();
        p.insns[1] = Insn::LoadWeights { pe: 0, seg: 1 }; // f32 segment
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_compute_before_config() {
        let mut p = sample();
        p.insns.remove(0);
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_bad_precision() {
        let mut p = sample();
        p.insns[0] = Insn::ConfigLayer { layer: 0, nb: 2, bh: 2, bw: 2, bits: 5, relu: true };
        assert!(p.validate().is_err());
    }

    #[test]
    fn disassembly_mentions_every_insn() {
        let asm = sample().disassemble();
        for needle in ["cfg.layer", "ld.w", "ld.b", "ld.s", "route", "compute", "scatter", "halt"] {
            assert!(asm.contains(needle), "missing {needle} in:\n{asm}");
        }
        assert_eq!(asm.lines().count(), 8);
    }

    #[test]
    fn foldadd_params_must_be_one_buffer_id() {
        let mut p = sample();
        // segment 1 is a 2-element f32 segment: not a [src_buf] scalar
        p.insns.insert(7, Insn::HostOp { op: HostOpKind::FoldAdd, seg: 1 });
        assert!(p.validate().is_err());
        let mut q = sample();
        let s = q.push_data(DataSegment::F32(vec![1.0]));
        q.insns.insert(7, Insn::HostOp { op: HostOpKind::FoldAdd, seg: s });
        q.validate().unwrap();
    }

    #[test]
    fn segment_accessors_type_check() {
        let p = sample();
        assert!(p.segment(0).unwrap().as_i8().is_ok());
        assert!(p.segment(0).unwrap().as_f32().is_err());
        assert!(p.segment(99).is_err());
    }
}
