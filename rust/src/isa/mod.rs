//! APU instruction set — the RoCC-shaped command stream (paper §4.1–4.2).
//!
//! The silicon prototype couples the accelerator to a Rocket RISC-V core
//! through the RoCC interface: custom instructions carry commands and the
//! core services memory/control requests. Our compiler emits the same
//! split: an [`Insn`] stream (the custom-instruction trace the core would
//! issue) plus [`DataSegment`]s (the memory the core DMA-loads into PE
//! SRAMs). The cycle-accurate simulator executes programs directly; the
//! assembler/disassembler give the human-readable form used in tests and
//! the `apu compile --emit-asm` flow.

pub mod artifact;
pub mod encode;
pub mod program;

pub use artifact::fingerprint_bytes;
pub use encode::{decode_insn, encode_insn};
pub use program::{DataSegment, HostOpKind, Insn, Program};
