//! Figure regeneration: one function per paper table/figure, producing the
//! same rows/series the paper reports. Used by the `apu figures` CLI and
//! timed by the `benches/` harnesses. EXPERIMENTS.md records
//! paper-vs-measured for every entry here.

use anyhow::Result;

use crate::baselines::EieModel;
use crate::compiler::cost::{cost_network, CostModel, MappingCase};
use crate::generator::{sweep_block_size, sweep_precision, DesignInstance, GeneratorConfig};
use crate::hwmodel::{pe_area, pe_energy_per_cycle, PeConfig, PeMode, Tech};
use crate::nn::{zoo, LayerKind, Network};
use crate::routing::RoutingDesign;
use crate::util::table::{eng, Table};

/// Fig. 3: temporal vs spatial PE — per-component area and energy at
/// 400×400 INT4.
pub fn fig3() -> Table {
    let tech = Tech::tsmc16();
    let cfg = PeConfig { block_h: 400, block_w: 400, bits: 4 };
    let mut t = Table::new(&["component", "temporal_pj", "spatial_pj", "temporal_mm2", "spatial_mm2"]);
    let te = pe_energy_per_cycle(&tech, &cfg, PeMode::Temporal);
    let se = pe_energy_per_cycle(&tech, &cfg, PeMode::Spatial);
    let ta = pe_area(&tech, &cfg, PeMode::Temporal);
    let sa = pe_area(&tech, &cfg, PeMode::Spatial);
    t.row(&["weight_sram".into(), eng(te.weight_sram_pj), eng(se.weight_sram_pj), eng(ta.weight_sram_mm2), eng(sa.weight_sram_mm2)]);
    t.row(&["multipliers".into(), eng(te.multipliers_pj), eng(se.multipliers_pj), eng(ta.multipliers_mm2), eng(sa.multipliers_mm2)]);
    t.row(&["adders".into(), eng(te.adders_pj), eng(se.adders_pj), eng(ta.adders_mm2), eng(sa.adders_mm2)]);
    t.row(&["regfile".into(), eng(te.regfile_pj), eng(se.regfile_pj), eng(ta.regfile_mm2), eng(sa.regfile_mm2)]);
    t.row(&["total".into(), eng(te.total()), eng(se.total()), eng(ta.total()), eng(sa.total())]);
    t
}

/// Fig. 4b: PE power breakdown per task (400×400 INT4 spatial).
pub fn fig4b() -> Table {
    let tech = Tech::tsmc16();
    let cfg = PeConfig { block_h: 400, block_w: 400, bits: 4 };
    let e = pe_energy_per_cycle(&tech, &cfg, PeMode::Spatial);
    let total = e.total();
    let mut t = Table::new(&["component", "pj_per_cycle", "share_pct"]);
    let mut row = |name: &str, v: f64| {
        t.row(&[name.into(), eng(v), format!("{:.1}", 100.0 * v / total)]);
    };
    row("weight_sram", e.weight_sram_pj);
    row("out+select_sram", e.out_sram_pj + e.select_sram_pj);
    row("multipliers", e.multipliers_pj);
    row("adder_tree", e.adders_pj);
    row("relu+quant", e.relu_quant_pj);
    row("latch+bcast", e.input_latch_pj + e.broadcast_pj);
    row("control", e.control_pj);
    row("TOTAL", total);
    t
}

/// Fig. 6: routing-network config memory vs data size N.
pub fn fig6() -> Table {
    let mut t = Table::new(&["N", "mux_bits", "clos_bits", "crossbar_bits", "clos/mux", "xbar/mux"]);
    for &n in &[64usize, 128, 256, 512, 1024, 2048, 4096] {
        let mux = RoutingDesign::Mux { n_pes: 10 }.config_bits(n);
        let clos = RoutingDesign::Clos.config_bits(n);
        let xbar = RoutingDesign::Crossbar.config_bits(n);
        t.row(&[n.to_string(), eng(mux), eng(clos), eng(xbar), eng(clos / mux), eng(xbar / mux)]);
    }
    t
}

/// Fig. 9: the chip specification table for the taped-out instance.
pub fn fig9() -> Result<(Table, DesignInstance)> {
    let inst = DesignInstance::generate(GeneratorConfig::default())?;
    let m = &inst.metrics;
    let mut t = Table::new(&["spec", "paper", "model"]);
    t.row(&["technology".into(), "16nm TSMC".into(), "16nm (modeled)".into()]);
    t.row(&["chip mm2".into(), "6.25".into(), eng(m.area_mm2)]);
    t.row(&["precision".into(), "4-bit".into(), format!("{}-bit", inst.config.bits)]);
    t.row(&["on-chip SRAM".into(), "1 MB".into(), format!("{:.2} MB", m.sram_bits as f64 / 8e6)]);
    t.row(&["PEs".into(), "10".into(), inst.config.n_pes.to_string()]);
    t.row(&["clock".into(), "1 GHz".into(), format!("{} GHz", inst.config.clock_ghz)]);
    t.row(&["power mW".into(), "440".into(), eng(m.power_mw)]);
    t.row(&["TOPS".into(), "16".into(), eng(m.tops)]);
    t.row(&["TOPS/W".into(), "36 (§4.3) / 46 (fig9)".into(), eng(m.tops_per_watt)]);
    t.row(&["layer cycles".into(), "400".into(), m.layer_cycles.to_string()]);
    Ok((t, inst))
}

/// Figs. 10a/11a: area and energy vs PE block size.
pub fn fig10_11_block() -> Result<Table> {
    let pts = sweep_block_size(&[200, 400, 800, 1024, 1600, 2048], 4)?;
    let mut t = Table::new(&["block", "compute_pj", "memory_pj", "total_pj", "compute_mm2", "memory_mm2", "total_mm2"]);
    for p in pts {
        t.row(&[
            p.x.to_string(),
            eng(p.compute_energy_pj),
            eng(p.memory_energy_pj),
            eng(p.total_energy_pj),
            eng(p.compute_area_mm2),
            eng(p.memory_area_mm2),
            eng(p.total_area_mm2),
        ]);
    }
    Ok(t)
}

/// Figs. 10b/11b: area and energy vs precision at 400×400.
pub fn fig10_11_precision() -> Result<Table> {
    let pts = sweep_precision(&[4, 8, 16])?;
    let mut t = Table::new(&["bits", "compute_pj", "memory_pj", "compute/memory", "compute_mm2", "memory_mm2"]);
    for p in pts {
        t.row(&[
            p.x.to_string(),
            eng(p.compute_energy_pj),
            eng(p.memory_energy_pj),
            eng(p.compute_energy_pj / p.memory_energy_pj),
            eng(p.compute_area_mm2),
            eng(p.memory_area_mm2),
        ]);
    }
    Ok(t)
}

/// Per-layer speedup + utilization of the APU (group conv, structured FC)
/// vs the EIE-style unstructured baseline, for Figs. 13 (VGG-19) and
/// 14 (ResNet-50).
pub fn conv_speedup_table(net: &Network, eie: &EieModel) -> Result<Table> {
    let model = CostModel::paper_9pe();
    let cost = cost_network(&model, net)?;
    let shapes = net.shapes()?;
    let mut t = Table::new(&["layer", "case", "apu_cycles", "eie_cycles", "speedup", "utilization_pct"]);
    for (i, (l, c)) in net.layers.iter().zip(&cost.layers).enumerate() {
        let (inp, outp) = (shapes[i], shapes[i + 1]);
        let eie_cycles = match &l.kind {
            LayerKind::Conv { cout, kh, kw, .. } => {
                eie.conv_cost(outp.h * outp.w, *cout, kh * kw * inp.c)?.total_cycles()
            }
            LayerKind::Fc { dout } => eie.fc_cost(*dout, inp.flat())?.total_cycles(),
            _ => 0,
        };
        let apu_cycles = c.total_cycles();
        let speedup = if apu_cycles == 0 || eie_cycles == 0 {
            0.0
        } else {
            eie_cycles as f64 / apu_cycles as f64
        };
        t.row(&[
            c.name.clone(),
            format!("{:?}", c.case),
            apu_cycles.to_string(),
            eie_cycles.to_string(),
            eng(speedup),
            format!("{:.1}", c.utilization * 100.0),
        ]);
    }
    Ok(t)
}

pub fn fig13() -> Result<Table> {
    conv_speedup_table(&zoo::vgg19(true), &EieModel::default())
}

pub fn fig14() -> Result<Table> {
    conv_speedup_table(&zoo::resnet50(true), &EieModel::default())
}

/// Fig. 15: structured vs unstructured (EIE) on the big FC layers,
/// 512×512 PE memory, 9 PEs both sides.
pub fn fig15() -> Result<Table> {
    let mut model = CostModel::paper_9pe();
    model.pe_h = 512;
    model.pe_w = 512;
    let eie = EieModel { sram_bits: 9 * 512 * 512 * 4, ..Default::default() };
    // The paper's x-axis: AlexNet FC6-8, VGG FC6-7.
    let layers: &[(&str, usize, usize)] = &[
        ("AlexFC6", 9216, 4096),
        ("AlexFC7", 4096, 4096),
        ("AlexFC8", 4096, 1000),
        ("VGGFC6", 25088, 4096),
        ("VGGFC7", 4096, 4096),
    ];
    let mut t = Table::new(&["layer", "apu_cycles", "apu_waves", "apu_streams", "eie_cycles", "speedup"]);
    for &(name, din, dout) in layers {
        // structured density 10% where divisible, else nearest divisor
        let nb = (2..=16).rev().find(|nb| din % nb == 0 && dout % nb == 0).unwrap_or(1);
        let net = Network {
            name: name.into(),
            input: crate::nn::graph::Shape { h: 1, w: 1, c: din },
            layers: vec![crate::nn::Layer { name: name.into(), kind: LayerKind::Fc { dout }, relu: true }],
        };
        let mut m = model.clone();
        m.fc_blocks = Some(nb);
        let apu = cost_network(&m, &net)?;
        let a = &apu.layers[0];
        let e = eie.fc_cost(dout, din)?;
        let speedup = e.total_cycles() as f64 / a.total_cycles() as f64;
        t.row(&[
            name.into(),
            a.total_cycles().to_string(),
            a.waves.to_string(),
            (a.stream_cycles > 0).to_string(),
            e.total_cycles().to_string(),
            eng(speedup),
        ]);
    }
    Ok(t)
}

/// The §4.3 headline claims from the generated Fig. 9 instance.
pub fn headline_claims() -> Result<Table> {
    let inst = DesignInstance::generate(GeneratorConfig::default())?;
    let m = &inst.metrics;
    let gops_per_pe = 4.0 * inst.config.block_w as f64 * inst.config.clock_ghz;
    let mut t = Table::new(&["claim", "paper", "model"]);
    t.row(&["GOPS per PE".into(), "1600".into(), eng(gops_per_pe)]);
    t.row(&["total TOPS".into(), "16".into(), eng(m.tops)]);
    t.row(&["TOPS/W".into(), "36".into(), eng(m.tops_per_watt)]);
    t.row(&["single-layer cycles".into(), "400".into(), m.layer_cycles.to_string()]);
    Ok(t)
}

/// Quick sanity aggregates used by tests and the CLI `figures all` run.
pub fn fig13_14_summary() -> Result<(f64, f64, f64, f64)> {
    let model = CostModel::paper_9pe();
    let eie = EieModel::default();
    let max_speedup = |net: &Network| -> Result<(f64, f64)> {
        let cost = cost_network(&model, net)?;
        let shapes = net.shapes()?;
        let mut best = 0f64;
        for (i, (l, c)) in net.layers.iter().zip(&cost.layers).enumerate() {
            if let LayerKind::Conv { cout, kh, kw, .. } = &l.kind {
                let (inp, outp) = (shapes[i], shapes[i + 1]);
                let e = eie.conv_cost(outp.h * outp.w, *cout, kh * kw * inp.c)?.total_cycles();
                best = best.max(e as f64 / c.total_cycles() as f64);
            }
        }
        let conv_util: Vec<f64> = cost
            .layers
            .iter()
            .filter(|c| matches!(c.case, MappingCase::ConvGroup | MappingCase::ConvSmall | MappingCase::ConvLarge))
            .map(|c| c.utilization)
            .collect();
        let util = conv_util.iter().sum::<f64>() / conv_util.len() as f64;
        Ok((best, util))
    };
    let (vgg_speed, vgg_util) = max_speedup(&zoo::vgg19(true))?;
    let (res_speed, res_util) = max_speedup(&zoo::resnet50(true))?;
    Ok((vgg_speed, vgg_util, res_speed, res_util))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_render() {
        assert!(fig3().render().contains("regfile"));
        assert!(fig4b().render().contains("weight_sram"));
        assert!(fig6().render().contains("4096"));
        let (t, _) = fig9().unwrap();
        assert!(t.render().contains("TOPS/W"));
        assert!(fig10_11_block().unwrap().render().contains("2048"));
        assert!(fig10_11_precision().unwrap().render().contains("16"));
        assert!(fig13().unwrap().render().contains("conv5_4"));
        assert!(fig14().unwrap().render().contains("res5_3_1x1b"));
        assert!(fig15().unwrap().render().contains("VGGFC6"));
        assert!(headline_claims().unwrap().render().contains("1600"));
    }

    #[test]
    fn fig13_14_shape_holds() {
        // Paper: VGG conv speedup up to ~50×, ResNet up to ~150×; ResNet's
        // best beats VGG's best; conv utilization near 100%.
        let (vgg, vgg_util, res, res_util) = fig13_14_summary().unwrap();
        assert!(vgg > 10.0, "VGG best speedup {vgg} should be >>1");
        assert!(res > vgg, "ResNet ({res}) should beat VGG ({vgg})");
        assert!(vgg_util > 0.9, "VGG conv utilization {vgg_util}");
        assert!(res_util > 0.85, "ResNet conv utilization {res_util}");
    }

    #[test]
    fn fig15_shape_holds() {
        // Structured wins on every layer except the folding dip at VGGFC6,
        // where the advantage collapses toward ~2× (streaming parity).
        let t = fig15().unwrap();
        let rendered = t.render();
        let rows: Vec<&str> = rendered.lines().skip(2).collect();
        let speedup_of = |name: &str| -> f64 {
            rows.iter()
                .find(|r| r.contains(name))
                .and_then(|r| r.split_whitespace().last())
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        let alex7 = speedup_of("AlexFC7");
        let vgg6 = speedup_of("VGGFC6");
        assert!(alex7 > 2.0, "AlexFC7 speedup {alex7}");
        assert!(vgg6 < alex7, "VGGFC6 ({vgg6}) must dip below AlexFC7 ({alex7})");
        assert!(vgg6 > 1.0, "structured should still win at VGGFC6: {vgg6}");
    }

    #[test]
    fn fig6_orders_of_magnitude() {
        let t = fig6();
        let r = t.render();
        // at N=4096 the crossbar/mux gap exceeds two orders of magnitude
        let line = r.lines().find(|l| l.starts_with(" 4096") || l.trim_start().starts_with("4096")).unwrap();
        let xbar_over_mux: f64 = line.split_whitespace().last().unwrap().parse().unwrap();
        assert!(xbar_over_mux > 100.0, "xbar/mux at 4096: {xbar_over_mux}");
    }
}
