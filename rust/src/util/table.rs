//! Aligned console tables — every figure harness prints its series as the
//! same rows the paper's plot shows, via this formatter.

/// Column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.header);
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }
}

/// Format a float with engineering-friendly precision.
pub fn eng(v: f64) -> String {
    let a = v.abs();
    if a == 0.0 {
        "0".to_string()
    } else if a >= 1000.0 {
        format!("{v:.0}")
    } else if a >= 10.0 {
        format!("{v:.1}")
    } else if a >= 0.01 {
        format!("{v:.3}")
    } else {
        format!("{v:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["short".into(), "1".into()]);
        t.row(&["a-much-longer-name".into(), "12345".into()]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name") && lines[0].contains("value"));
        // all data lines share the same width
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        Table::new(&["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn eng_formats() {
        assert_eq!(eng(0.0), "0");
        assert_eq!(eng(12345.6), "12346");
        assert_eq!(eng(42.42), "42.4");
        assert_eq!(eng(1.5), "1.500");
        assert_eq!(eng(0.00001), "1.00e-5");
    }
}
