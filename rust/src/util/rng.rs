//! Deterministic PRNG: SplitMix64 seeding into xoshiro256**.
//!
//! Every stochastic component in the framework (mask generation, workload
//! synthesis, property tests, the coordinator's synthetic arrival process)
//! takes an explicit seed through this generator, so every experiment and
//! every test failure is exactly reproducible.

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Exponential with the given rate (coordinator arrival processes).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Split off an independent child generator (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::new(4);
        let p = r.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<u32>>());
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork();
        let mut b = r.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
