//! Streaming summary statistics: Welford mean/variance plus exact
//! percentiles over a retained sample. Used by the bench harness and the
//! coordinator's latency/throughput accounting.

/// Online summary of a stream of f64 observations.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Summary { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile with linear interpolation on the retained sample.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = (p / 100.0) * (self.samples.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_sequence() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.median(), 4.5);
    }

    #[test]
    fn percentiles_on_uniform_ramp() {
        let mut s = Summary::new();
        for i in 0..=100 {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn empty_and_single() {
        let mut s = Summary::new();
        assert!(s.percentile(50.0).is_nan());
        s.add(3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.variance(), 0.0);
    }
}
