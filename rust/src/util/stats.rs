//! Streaming summary statistics: Welford mean/variance plus exact
//! percentiles over a retained sample. Used by the bench harness and the
//! coordinator's latency/throughput accounting.

use crate::util::json::Json;

/// Online summary of a stream of f64 observations.
///
/// Non-finite observations (a NaN latency from a bad clock, an ∞ from a
/// zero-interval division) are counted in [`Summary::dropped`] and
/// otherwise ignored: the serving path's SLO tables must survive bad
/// samples, not abort a shard on them.
#[derive(Debug, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    sorted: bool,
    dropped: u64,
}

impl Default for Summary {
    /// Same as [`Summary::new`] — a derived `Default` would start
    /// `min`/`max` at 0.0 and corrupt the extrema of positive streams.
    fn default() -> Self {
        Summary::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: Vec::new(),
            sorted: false,
            dropped: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if !x.is_finite() {
            self.dropped += 1;
            return;
        }
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Non-finite observations rejected by [`Summary::add`].
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile with linear interpolation on the retained sample.
    /// Out-of-range `p` clamps to `[0, 100]` and a non-finite `p` yields
    /// NaN — never a panic (this runs inside fleet SLO reporting).
    pub fn percentile(&mut self, p: f64) -> f64 {
        if !p.is_finite() || self.samples.is_empty() {
            return f64::NAN;
        }
        let p = p.clamp(0.0, 100.0);
        if !self.sorted {
            // total_cmp: no partial_cmp unwrap to abort on (the samples
            // are finite by construction, but stay panic-free anyway)
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
        let rank = (p / 100.0) * (self.samples.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// Fold another summary into this one (fleet-wide SLO aggregation:
    /// per-shard latency streams merge into one distribution, so the
    /// combined percentiles are exact, not an average of percentiles).
    pub fn merge(&mut self, other: &Summary) {
        for &x in &other.samples {
            self.add(x);
        }
        self.dropped += other.dropped;
    }

    /// The summary as a JSON object (count/mean/min/max/p50/p95/p99).
    /// `&mut self` because percentiles sort the retained sample; on an
    /// empty summary the non-finite fields serialize as `null`.
    pub fn to_json(&mut self) -> Json {
        Json::obj(vec![
            ("count", Json::Int(self.n as i64)),
            ("dropped", Json::Int(self.dropped as i64)),
            ("mean", Json::num(self.mean())),
            ("stddev", Json::num(self.stddev())),
            ("min", Json::num(self.min())),
            ("max", Json::num(self.max())),
            ("p50", Json::num(self.p50())),
            ("p95", Json::num(self.p95())),
            ("p99", Json::num(self.p99())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_sequence() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.median(), 4.5);
    }

    #[test]
    fn percentiles_on_uniform_ramp() {
        let mut s = Summary::new();
        for i in 0..=100 {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn p50_p95_p99_on_latency_like_stream() {
        // 1..=1000 us: p50=500.5, p95=950.05, p99=990.01 under linear
        // interpolation over the 1000-sample ramp.
        let mut s = Summary::new();
        for i in 1..=1000 {
            s.add(i as f64);
        }
        assert!((s.p50() - 500.5).abs() < 1e-9);
        assert!((s.p95() - 950.05).abs() < 1e-9);
        assert!((s.p99() - 990.01).abs() < 1e-9);
    }

    #[test]
    fn merge_combines_distributions_exactly() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut whole = Summary::new();
        for i in 0..50 {
            a.add(i as f64);
            whole.add(i as f64);
        }
        for i in 50..100 {
            b.add(i as f64);
            whole.add(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.p95() - whole.p95()).abs() < 1e-9);
        assert_eq!(a.min(), 0.0);
        assert_eq!(a.max(), 99.0);
    }

    #[test]
    fn default_tracks_extrema_like_new() {
        let mut s = Summary::default();
        s.add(5.0);
        s.add(3.0);
        assert_eq!(s.min(), 3.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn merge_into_empty() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        b.add(7.0);
        b.add(9.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.median(), 8.0);
    }

    #[test]
    fn non_finite_samples_are_dropped_not_propagated() {
        let mut s = Summary::new();
        for x in [1.0, f64::NAN, 2.0, f64::INFINITY, 3.0, f64::NEG_INFINITY] {
            s.add(x);
        }
        assert_eq!(s.count(), 3);
        assert_eq!(s.dropped(), 3);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert_eq!(s.median(), 2.0);
        assert!(s.mean().is_finite());
        // dropped counts survive a fleet-style merge
        let mut whole = Summary::new();
        whole.merge(&s);
        assert_eq!(whole.count(), 3);
        assert_eq!(whole.dropped(), 3);
    }

    #[test]
    fn out_of_range_percentiles_clamp_instead_of_panicking() {
        let mut s = Summary::new();
        for i in 0..10 {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(-5.0), 0.0);
        assert_eq!(s.percentile(150.0), 9.0);
        assert!(s.percentile(f64::NAN).is_nan());
        assert!(s.percentile(f64::INFINITY).is_nan());
    }

    #[test]
    fn to_json_round_trips_even_when_empty() {
        let mut s = Summary::new();
        for i in 1..=4 {
            s.add(i as f64);
        }
        let parsed = Json::parse(&s.to_json().pretty()).unwrap();
        assert_eq!(parsed.get("count"), Some(&Json::Int(4)));
        assert_eq!(parsed.get("p50"), Some(&Json::Num(2.5)));
        // empty summary: ±inf extrema and NaN percentiles must become
        // null, not invalid JSON
        let empty = Summary::new().to_json().pretty();
        let parsed = Json::parse(&empty).unwrap();
        assert_eq!(parsed.get("min"), Some(&Json::Null));
        assert_eq!(parsed.get("p99"), Some(&Json::Null));
    }

    #[test]
    fn empty_and_single() {
        let mut s = Summary::new();
        assert!(s.percentile(50.0).is_nan());
        s.add(3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.variance(), 0.0);
    }
}
