//! Infrastructure substrates built from scratch for the offline environment.
//!
//! The vendored crate set only covers the `xla` closure, so the framework
//! carries its own implementations of the utilities a production system
//! would normally pull from crates.io (documented in DESIGN.md §2):
//!
//! * [`json`] — a small, strict JSON parser/serializer (model graphs,
//!   artifact manifests, figure reports).
//! * [`rng`] — deterministic SplitMix64/xoshiro256** PRNG (mask generation,
//!   workload synthesis, property tests).
//! * [`bundle`] — reader for the python-side tensor bundles
//!   (`*.json` manifest + raw little-endian `*.bin` blob).
//! * [`stats`] — streaming summary statistics for benches and the
//!   coordinator's latency accounting.
//! * [`cli`] — a tiny declarative flag parser for the `apu` binary.
//! * [`table`] — aligned console tables for figure/benchmark output.

pub mod bench;
pub mod bundle;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
