//! Reader for the python compile path's tensor bundles.
//!
//! A bundle is a JSON manifest (`{"tensors": {name: {dtype, shape, offset,
//! bytes}}, ...}`) plus a raw little-endian binary blob, written by
//! `python/compile/aot.py::BundleWriter`. This is the only channel through
//! which trained weights, permutations, and golden test vectors cross the
//! python→rust boundary.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::json::Json;

/// A typed tensor view decoded from a bundle.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I8(v) => v.len(),
            Tensor::I32(v) => v.len(),
            Tensor::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            Tensor::I8(v) => Ok(v),
            _ => bail!("tensor is not i8"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            Tensor::U32(v) => Ok(v),
            _ => bail!("tensor is not u32"),
        }
    }
}

/// A loaded bundle: tensors by name, shapes, and the manifest for
/// free-form metadata access.
#[derive(Debug)]
pub struct Bundle {
    pub tensors: BTreeMap<String, (Vec<usize>, Tensor)>,
    pub manifest: Json,
}

impl Bundle {
    /// Load `<stem>.json` + the blob it names (relative to the manifest).
    pub fn load(manifest_path: impl AsRef<Path>) -> Result<Bundle> {
        let manifest_path = manifest_path.as_ref();
        let text = std::fs::read_to_string(manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Json::parse(&text).with_context(|| format!("parsing {}", manifest_path.display()))?;
        let bin_name = manifest
            .get("bin")
            .and_then(Json::as_str)
            .context("manifest missing 'bin'")?;
        let bin_path = manifest_path.parent().unwrap_or(Path::new(".")).join(bin_name);
        let blob = std::fs::read(&bin_path).with_context(|| format!("reading {}", bin_path.display()))?;

        let mut tensors = BTreeMap::new();
        let tmap = manifest
            .get("tensors")
            .and_then(Json::as_obj)
            .context("manifest missing 'tensors'")?;
        for (name, meta) in tmap {
            let dtype = meta.get("dtype").and_then(Json::as_str).context("tensor missing dtype")?;
            let shape: Vec<usize> = meta
                .get("shape")
                .and_then(Json::as_arr)
                .context("tensor missing shape")?
                .iter()
                .map(|j| j.as_usize().context("bad shape entry"))
                .collect::<Result<_>>()?;
            let offset = meta.get("offset").and_then(Json::as_usize).context("tensor missing offset")?;
            let nbytes = meta.get("bytes").and_then(Json::as_usize).context("tensor missing bytes")?;
            if offset + nbytes > blob.len() {
                bail!("tensor {name} [{offset}..{}] exceeds blob ({} bytes)", offset + nbytes, blob.len());
            }
            let raw = &blob[offset..offset + nbytes];
            let numel: usize = shape.iter().product();
            let t = match dtype {
                "f32" => {
                    ensure_size(name, raw.len(), numel, 4)?;
                    Tensor::F32(raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
                }
                "i8" => {
                    ensure_size(name, raw.len(), numel, 1)?;
                    Tensor::I8(raw.iter().map(|&b| b as i8).collect())
                }
                "i32" => {
                    ensure_size(name, raw.len(), numel, 4)?;
                    Tensor::I32(raw.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
                }
                "u32" => {
                    ensure_size(name, raw.len(), numel, 4)?;
                    Tensor::U32(raw.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
                }
                other => bail!("unsupported dtype {other} for tensor {name}"),
            };
            tensors.insert(name.clone(), (shape, t));
        }
        Ok(Bundle { tensors, manifest })
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .map(|(_, t)| t)
            .with_context(|| format!("bundle missing tensor {name}"))
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        self.tensors
            .get(name)
            .map(|(s, _)| s.as_slice())
            .with_context(|| format!("bundle missing tensor {name}"))
    }
}

fn ensure_size(name: &str, raw: usize, numel: usize, elem: usize) -> Result<()> {
    if raw != numel * elem {
        bail!("tensor {name}: {raw} bytes but shape implies {}", numel * elem);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_bundle(dir: &Path) -> std::path::PathBuf {
        let f32s: Vec<f32> = vec![1.5, -2.0, 3.25];
        let i8s: Vec<i8> = vec![-7, 0, 7, 3];
        let mut blob: Vec<u8> = Vec::new();
        for v in &f32s {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        let i8_off = blob.len();
        blob.extend(i8s.iter().map(|&v| v as u8));
        let manifest = format!(
            r#"{{"bin": "t.bin", "tensors": {{
              "a": {{"dtype": "f32", "shape": [3], "offset": 0, "bytes": 12}},
              "b": {{"dtype": "i8", "shape": [2, 2], "offset": {i8_off}, "bytes": 4}}
            }}, "bits": 4}}"#
        );
        std::fs::File::create(dir.join("t.bin")).unwrap().write_all(&blob).unwrap();
        let mp = dir.join("t.json");
        std::fs::File::create(&mp).unwrap().write_all(manifest.as_bytes()).unwrap();
        mp
    }

    #[test]
    fn loads_and_types() {
        let dir = std::env::temp_dir().join(format!("apu_bundle_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mp = write_bundle(&dir);
        let b = Bundle::load(&mp).unwrap();
        assert_eq!(b.tensor("a").unwrap().as_f32().unwrap(), &[1.5, -2.0, 3.25]);
        assert_eq!(b.tensor("b").unwrap().as_i8().unwrap(), &[-7, 0, 7, 3]);
        assert_eq!(b.shape("b").unwrap(), &[2, 2]);
        assert_eq!(b.manifest.get("bits").and_then(Json::as_i64), Some(4));
        assert!(b.tensor("missing").is_err());
        assert!(b.tensor("a").unwrap().as_i8().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
