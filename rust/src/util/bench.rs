//! Micro-benchmark harness (criterion is unavailable offline; this is the
//! substrate substitute — warmup, fixed-duration sampling, summary stats).

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Summary;

/// Result of one benchmark.
#[derive(Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.0} ns/iter (median {:>10.0}, min {:>10.0}, sd {:>8.0}, n={})",
            self.name, self.mean_ns, self.median_ns, self.min_ns, self.stddev_ns, self.iters
        )
    }

    /// Throughput helper: items per second given items processed per iter.
    pub fn per_second(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.as_str())),
            ("iters", Json::Int(self.iters as i64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("median_ns", Json::num(self.median_ns)),
            ("stddev_ns", Json::num(self.stddev_ns)),
            ("min_ns", Json::num(self.min_ns)),
        ])
    }
}

/// Write a machine-readable bench report (one entry per result) — the
/// perf-trajectory artifact `ci.sh` tracks across PRs.
///
/// Merges by name with any report already at `path`: entries whose names
/// match the new results are replaced, everything else is kept. This lets
/// separate bench binaries (sim_hotpath, fleet_scaling) contribute to one
/// BENCH_*.json without clobbering each other.
pub fn write_report(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let fresh: Vec<&str> = results.iter().map(|r| r.name.as_str()).collect();
    let mut merged: Vec<Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|doc| match doc.path("benches") {
            Some(Json::Arr(prev)) => Some(prev.clone()),
            _ => None,
        })
        .unwrap_or_default()
        .into_iter()
        .filter(|b| b.path("name").and_then(Json::as_str).is_some_and(|n| !fresh.contains(&n)))
        .collect();
    merged.extend(results.iter().map(BenchResult::to_json));
    let doc = Json::obj(vec![
        ("benches", Json::Arr(merged)),
        ("budget_ms", Json::Int(budget().as_millis() as i64)),
    ]);
    std::fs::write(path, doc.pretty())
}

/// Time `f` for ~`budget` after a short warmup. `f` returns a value that
/// is black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup
    let warm_until = Instant::now() + budget / 10;
    while Instant::now() < warm_until {
        std::hint::black_box(f());
    }
    let mut s = Summary::new();
    let until = Instant::now() + budget;
    let mut iters = 0u64;
    while Instant::now() < until {
        let t0 = Instant::now();
        std::hint::black_box(f());
        s.add(t0.elapsed().as_nanos() as f64);
        iters += 1;
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: s.mean(),
        median_ns: s.median(),
        stddev_ns: s.stddev(),
        min_ns: s.min(),
    }
}

/// Standard per-bench budget (override with APU_BENCH_MS).
pub fn budget() -> Duration {
    let ms = std::env::var("APU_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(300u64);
    Duration::from_millis(ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_a_trivial_closure() {
        let r = bench("noop", Duration::from_millis(20), || 1 + 1);
        assert!(r.iters > 100);
        assert!(r.mean_ns >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn write_report_merges_by_name() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("apu-bench-merge-{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let mk = |name: &str, mean: f64| BenchResult {
            name: name.into(),
            iters: 1,
            mean_ns: mean,
            median_ns: mean,
            stddev_ns: 0.0,
            min_ns: mean,
        };
        write_report(&path, &[mk("a", 1.0), mk("b", 2.0)]).unwrap();
        // second writer updates "b" and adds "c"; "a" must survive
        write_report(&path, &[mk("b", 20.0), mk("c", 3.0)]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let _ = std::fs::remove_file(&path);
        let Some(Json::Arr(benches)) = doc.path("benches") else {
            panic!("no benches array");
        };
        let mut seen: Vec<(String, f64)> = benches
            .iter()
            .map(|b| {
                let name = b.path("name").and_then(Json::as_str).unwrap().to_string();
                let mean = match b.path("mean_ns") {
                    Some(Json::Num(x)) => *x,
                    Some(Json::Int(x)) => *x as f64,
                    other => panic!("bad mean_ns {other:?}"),
                };
                (name, mean)
            })
            .collect();
        seen.sort();
        assert_eq!(
            seen,
            vec![("a".to_string(), 1.0), ("b".to_string(), 20.0), ("c".to_string(), 3.0)]
        );
    }

    #[test]
    fn per_second_math() {
        let r = BenchResult { name: "x".into(), iters: 1, mean_ns: 1e9, median_ns: 1e9, stddev_ns: 0.0, min_ns: 1e9 };
        assert!((r.per_second(100.0) - 100.0).abs() < 1e-9);
    }
}
