//! Tiny declarative CLI flag parser for the `apu` binary (no clap offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generates usage text from the declared options.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Declared option: name, default (None = boolean flag), help line.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    pub values: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    /// Like [`Args::get`], but a missing value is a context-rich error
    /// instead of an `Option` (for options the command requires).
    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.values.get(name).ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        Ok(v.parse()?)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.values.get(name).ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        Ok(v.parse()?)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Parse `argv` against the declared options, filling defaults.
pub fn parse(argv: &[String], opts: &[Opt]) -> Result<Args> {
    let mut args = Args::default();
    for o in opts {
        if let Some(d) = o.default {
            args.values.insert(o.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(body) = a.strip_prefix("--") {
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let decl = opts.iter().find(|o| o.name == name);
            match decl {
                Some(o) if o.default.is_some() => {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        }
                    };
                    args.values.insert(name.to_string(), v);
                }
                Some(_) => args.flags.push(name.to_string()),
                None => bail!("unknown option --{name}"),
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render a usage block from the declared options.
pub fn usage(cmd: &str, summary: &str, opts: &[Opt]) -> String {
    let mut s = format!("{summary}\n\nUsage: apu {cmd} [options]\n\nOptions:\n");
    for o in opts {
        let left = match o.default {
            Some(d) => format!("  --{} <v> (default {})", o.name, d),
            None => format!("  --{}", o.name),
        };
        s.push_str(&format!("{left:<38} {}\n", o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> Vec<Opt> {
        vec![
            Opt { name: "pes", default: Some("10"), help: "number of PEs" },
            Opt { name: "verbose", default: None, help: "chatty" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn req_errors_name_the_option() {
        let a = parse(&sv(&[]), &opts()).unwrap();
        assert_eq!(a.req("pes").unwrap(), "10");
        let err = format!("{:#}", a.req("absent").unwrap_err());
        assert!(err.contains("--absent"), "{err}");
    }

    #[test]
    fn defaults_and_override() {
        let a = parse(&sv(&[]), &opts()).unwrap();
        assert_eq!(a.get_usize("pes").unwrap(), 10);
        let a = parse(&sv(&["--pes", "4"]), &opts()).unwrap();
        assert_eq!(a.get_usize("pes").unwrap(), 4);
        let a = parse(&sv(&["--pes=7"]), &opts()).unwrap();
        assert_eq!(a.get_usize("pes").unwrap(), 7);
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&sv(&["run", "--verbose", "x.json"]), &opts()).unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["run", "x.json"]);
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(parse(&sv(&["--nope"]), &opts()).is_err());
        assert!(parse(&sv(&["--pes"]), &opts()).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("sim", "Run the simulator", &opts());
        assert!(u.contains("--pes") && u.contains("number of PEs"));
    }
}
