//! Minimal strict JSON parser + serializer.
//!
//! Covers the full JSON grammar (RFC 8259) minus surrogate-pair escapes in
//! strings being split across escapes; numbers parse as `f64` with `i64`
//! fast-path preserved. This is the interchange layer between the python
//! compile path (model bundles, manifests) and the rust framework, and the
//! output format of every figure harness.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable diffs for generated reports).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integer fast-path: round-trips i64 exactly.
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure: byte offset into the source plus a short message.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access, `/`-separated.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('/') {
            cur = match cur {
                Json::Obj(m) => m.get(seg)?,
                Json::Arr(a) => a.get(seg.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Pretty-print with 1-space indent (matches python `json.dump(indent=1)`).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{:.1}", n));
                    } else {
                        out.push_str(&format!("{}", n));
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(d + 1));
                        v.write(out, Some(d + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let (Some(d), false) = (indent, a.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(d));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(d + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(d + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let (Some(d), false) = (indent, m.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(d));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("3.5e2").unwrap(), Json::Num(350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": null}, "x"], "c": -0.5}"#).unwrap();
        assert_eq!(v.path("a/1/b"), Some(&Json::Null));
        assert_eq!(v.path("a/2").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("c").and_then(Json::as_f64), Some(-0.5));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"s",false,null],"empty_arr":[],"nested":{"k":"v"},"unicode":"héllo"}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        let pretty = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn int_precision_preserved() {
        let big = i64::MAX - 1;
        let v = Json::parse(&big.to_string()).unwrap();
        assert_eq!(v.as_i64(), Some(big));
    }

    #[test]
    fn reads_python_style_manifest() {
        let doc = r#"{"tensors": {"l0.w_codes": {"dtype": "i8", "shape": [10, 30, 80], "offset": 0, "bytes": 24000}}, "bits": 4}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.path("tensors/l0.w_codes/shape/2").and_then(Json::as_usize), Some(80));
    }
}
