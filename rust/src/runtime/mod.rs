//! PJRT runtime: loads the AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! This is the **golden numeric model**: the exact computation the L2 JAX
//! graph (with the L1 Pallas kernel inlined, interpret-mode) performs.
//! The cycle-accurate simulator must agree with it; the coordinator can
//! serve from either engine. HLO *text* is the interchange format — see
//! DESIGN.md (jax ≥0.5 serialized protos are rejected by xla_extension
//! 0.5.1).
//!
//! The PJRT client lives behind the `pjrt` cargo feature (it links the
//! native `xla_extension` library). Without the feature, `Runtime` and
//! `Executable` are stubs that error at call time, so the rest of the
//! stack — simulator, coordinator, fleet — builds and runs everywhere.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// The PJRT CPU client (one per process).
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable { exe, path: path.to_path_buf() })
    }
}

/// A compiled artifact.
#[cfg(feature = "pjrt")]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    path: PathBuf,
}

#[cfg(feature = "pjrt")]
impl Executable {
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Execute with f32 inputs of the given shapes; returns the flattened
    /// f32 outputs (artifacts are lowered with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(shape)
                .map_err(|e| anyhow::anyhow!("reshape to {shape:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let buf = result
            .first()
            .and_then(|d| d.first())
            .context("executable returned no buffers")?;
        let mut lit = buf.to_literal_sync().map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // return_tuple=True wraps outputs in a tuple
        let elems = lit.decompose_tuple().map_err(|e| anyhow::anyhow!("decompose: {e:?}"))?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<f32>().map_err(|er| anyhow::anyhow!("to_vec: {er:?}"))?);
        }
        Ok(out)
    }
}

/// Stub runtime when built without the `pjrt` feature: construction
/// fails with a pointer at the feature flag, so callers get a clear
/// error instead of a link failure.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        bail!("built without the `pjrt` feature — rebuild with `--features pjrt` for the PJRT golden-model runtime")
    }

    pub fn platform(&self) -> String {
        "pjrt-disabled".to_string()
    }

    pub fn load_hlo_text(&self, _path: impl AsRef<Path>) -> Result<Executable> {
        bail!("built without the `pjrt` feature")
    }
}

/// Stub artifact handle when built without the `pjrt` feature; never
/// constructible (the stub `Runtime::cpu` already errors).
#[cfg(not(feature = "pjrt"))]
pub struct Executable {
    path: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        bail!("built without the `pjrt` feature")
    }
}

/// The artifact manifest written by `make artifacts`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub json: Json,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
        let json = Json::parse(&text)?;
        Ok(Manifest { dir, json })
    }

    /// Default artifact location (repo-root `artifacts/`).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let files = self.json.get("hlo").and_then(Json::as_arr).context("manifest missing hlo")?;
        let found = files.iter().filter_map(Json::as_str).find(|f| f.contains(name));
        match found {
            Some(f) => Ok(self.dir.join(f)),
            None => bail!("no HLO artifact matching {name}"),
        }
    }

    pub fn model_bundle_path(&self) -> PathBuf {
        self.dir.join("lenet_model.json")
    }

    pub fn testvec_path(&self) -> PathBuf {
        self.dir.join("testvec.json")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    fn artifacts() -> Option<Manifest> {
        Manifest::load(Manifest::default_dir()).ok()
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn golden_model_runs_testvec() {
        let Some(m) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(m.hlo_path("lenet_b1").unwrap()).unwrap();
        let tv = crate::util::bundle::Bundle::load(m.testvec_path()).unwrap();
        let x = tv.tensor("x").unwrap().as_f32().unwrap().to_vec();
        let want = tv.tensor("logits").unwrap().as_f32().unwrap().to_vec();
        let din = tv.shape("x").unwrap()[1];
        // run the first sample through the batch-1 artifact
        let out = exe.run_f32(&[(&x[..din], &[1, din as i64])]).unwrap();
        assert_eq!(out.len(), 1);
        let logits = &out[0];
        assert_eq!(logits.len(), 10);
        for (i, (&g, &w)) in logits.iter().zip(&want[..10]).enumerate() {
            assert!((g - w).abs() < 1e-3, "logit {i}: {g} vs {w}");
        }
    }

    #[test]
    fn manifest_errors_without_artifacts() {
        assert!(Manifest::load("/nonexistent").is_err());
    }
}
