//! Hardware design generator (paper §4.1, §4.4): the Chisel/Rocket-Chip
//! generator's role, reproduced as a parameterized design-instance
//! generator with a structural netlist description and per-instance
//! area/energy/performance reports, plus the design-space-exploration
//! sweeps behind Figs. 10 and 11.

pub mod dse;
pub mod instance;

pub use dse::{sweep_block_size, sweep_precision, DsePoint};
pub use instance::{DesignInstance, GeneratorConfig};
