//! Design-space exploration sweeps (paper §4.4.1–4.4.2, Figs. 10–11).

use anyhow::Result;

use super::instance::{DesignInstance, GeneratorConfig};
use crate::hwmodel::PeMode;

/// One DSE sample: the generated instance's PE-level area/energy split.
#[derive(Debug, Clone)]
pub struct DsePoint {
    /// Swept value (block dim or bit width).
    pub x: usize,
    pub compute_energy_pj: f64,
    pub memory_energy_pj: f64,
    pub compute_area_mm2: f64,
    pub memory_area_mm2: f64,
    pub total_energy_pj: f64,
    pub total_area_mm2: f64,
}

fn point(x: usize, cfg: GeneratorConfig) -> Result<DsePoint> {
    let inst = DesignInstance::generate(cfg)?;
    let (e, a) = inst.pe_report();
    Ok(DsePoint {
        x,
        compute_energy_pj: e.compute(),
        memory_energy_pj: e.memory(),
        compute_area_mm2: a.compute(),
        memory_area_mm2: a.memory(),
        total_energy_pj: e.total(),
        total_area_mm2: a.total(),
    })
}

/// Fig. 10a/11a: sweep the PE block size (square blocks, fixed 4-bit).
/// Paper sweeps 200..2048 per dimension.
pub fn sweep_block_size(sizes: &[usize], bits: u32) -> Result<Vec<DsePoint>> {
    sizes
        .iter()
        .map(|&s| {
            point(
                s,
                GeneratorConfig { block_h: s, block_w: s, bits, mode: PeMode::Spatial, ..Default::default() },
            )
        })
        .collect()
}

/// Fig. 10b/11b: sweep precision at a fixed 400×400 block.
pub fn sweep_precision(bits_list: &[u32]) -> Result<Vec<DsePoint>> {
    bits_list
        .iter()
        .map(|&b| {
            point(
                b as usize,
                GeneratorConfig { block_h: 400, block_w: 400, bits: b, mode: PeMode::Spatial, ..Default::default() },
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_sweep_shapes() {
        // Paper: compute scales linearly with block dim, memory quadratically.
        let pts = sweep_block_size(&[200, 400, 800, 1600], 4).unwrap();
        let growth = |f: fn(&DsePoint) -> f64| f(&pts[3]) / f(&pts[0]);
        let cg = growth(|p| p.compute_energy_pj);
        let mg = growth(|p| p.memory_energy_pj);
        assert!(cg > 6.0 && cg < 10.0, "compute energy growth {cg} (8× dim)");
        assert!(mg > cg * 2.0, "memory must outgrow compute: {mg} vs {cg}");
        let ca = growth(|p| p.compute_area_mm2);
        let ma = growth(|p| p.memory_area_mm2);
        assert!((ca - 8.0).abs() < 2.0, "compute area growth {ca}");
        assert!((ma - 64.0).abs() < 8.0, "memory area growth {ma} (quadratic)");
    }

    #[test]
    fn precision_sweep_break_even() {
        let pts = sweep_precision(&[4, 8, 16]).unwrap();
        // 4b: memory dominates; 8b: break-even; 16b: compute dominates
        assert!(pts[0].memory_energy_pj > 1.5 * pts[0].compute_energy_pj);
        let r8 = pts[1].compute_energy_pj / pts[1].memory_energy_pj;
        assert!((r8 - 1.0).abs() < 0.25, "8-bit ratio {r8}");
        assert!(pts[2].compute_energy_pj > 2.0 * pts[2].memory_energy_pj);
    }

    #[test]
    fn monotone_totals() {
        let pts = sweep_block_size(&[256, 512, 1024], 4).unwrap();
        assert!(pts.windows(2).all(|w| w[1].total_energy_pj > w[0].total_energy_pj));
        assert!(pts.windows(2).all(|w| w[1].total_area_mm2 > w[0].total_area_mm2));
    }
}
