//! Design-instance generation: parameters → structural netlist + metrics.

use anyhow::{bail, Result};

use crate::hwmodel::{chip_metrics, pe_area, pe_energy_per_cycle, ChipMetrics, PeConfig, PeMode, Tech};
use crate::sim::ApuConfig;
use crate::util::json::Json;

/// Generator parameters (the Chisel top-level's knobs, §4.1: "the internal
/// structure of the PE, the number of PEs, and the interconnect
/// infrastructure are flexible").
#[derive(Debug, Clone, PartialEq)]
pub struct GeneratorConfig {
    pub n_pes: usize,
    pub block_h: usize,
    pub block_w: usize,
    pub bits: u32,
    pub clock_ghz: f64,
    pub mode: PeMode,
}

impl Default for GeneratorConfig {
    /// The taped-out instance (paper Fig. 9).
    fn default() -> Self {
        GeneratorConfig { n_pes: 10, block_h: 400, block_w: 400, bits: 4, clock_ghz: 1.0, mode: PeMode::Spatial }
    }
}

impl GeneratorConfig {
    pub fn validate(&self) -> Result<()> {
        if self.n_pes == 0 || self.block_h == 0 || self.block_w == 0 {
            bail!("degenerate generator config");
        }
        if ![2, 4, 8, 16].contains(&self.bits) {
            bail!("unsupported precision {} (2/4/8/16)", self.bits);
        }
        if !(0.1..=4.0).contains(&self.clock_ghz) {
            bail!("clock {} GHz outside signoff range", self.clock_ghz);
        }
        Ok(())
    }

    pub fn pe_config(&self) -> PeConfig {
        PeConfig { block_h: self.block_h, block_w: self.block_w, bits: self.bits }
    }
}

/// A generated design instance: the netlist summary + analytic metrics +
/// the simulator configuration that executes it.
#[derive(Debug, Clone)]
pub struct DesignInstance {
    pub config: GeneratorConfig,
    pub metrics: ChipMetrics,
}

impl DesignInstance {
    /// Elaborate a design instance (the `rocket-chip` generate step).
    pub fn generate(config: GeneratorConfig) -> Result<DesignInstance> {
        config.validate()?;
        let tech = Tech::tsmc16();
        let metrics = chip_metrics(&tech, &config.pe_config(), config.n_pes, config.clock_ghz);
        Ok(DesignInstance { config, metrics })
    }

    /// The simulator configuration for this instance.
    pub fn apu_config(&self) -> ApuConfig {
        ApuConfig {
            n_pes: self.config.n_pes,
            pe_sram_bits: self.config.block_h * self.config.block_w * self.config.bits as usize,
            clock_ghz: self.config.clock_ghz,
        }
    }

    /// Structural netlist description: module hierarchy with instance
    /// counts and memory macros (what the Chisel elaboration would print).
    pub fn netlist(&self) -> String {
        let c = &self.config;
        let pe = c.pe_config();
        let tree_stages = (c.block_w as f64).log2().ceil() as usize;
        let mut s = String::new();
        s.push_str(&format!("module apu_top  // generated instance\n"));
        s.push_str(&format!("  rocket_core host (rv64imac, 16K I$ + 16K D$)\n"));
        s.push_str(&format!("  rocc_adapter cmd_queue (2-entry)\n"));
        s.push_str(&format!("  mux_crossbar xbar (radix {}, {}b lanes)\n", c.n_pes, c.bits));
        s.push_str(&format!("  pe_array [{}] {{\n", c.n_pes));
        s.push_str(&format!("    sram weight ({} x {}b rows = {} bits)\n", c.block_h, c.block_w * c.bits as usize, pe.weight_sram_bits()));
        s.push_str(&format!("    latch input ({} bits)\n", pe.input_latch_bits()));
        match c.mode {
            PeMode::Spatial => {
                s.push_str(&format!("    mult int{} [{}]\n", c.bits, c.block_w));
                s.push_str(&format!("    adder_tree ({} stages, widths {}..{})\n", tree_stages, c.bits + 1, c.bits as usize + tree_stages));
            }
            PeMode::Temporal => {
                s.push_str(&format!("    mult int{} [{}]\n", c.bits, c.block_h));
                s.push_str(&format!("    regfile psum ({} x {}b)\n", c.block_h, pe.acc_bits()));
            }
        }
        s.push_str(&format!("    relu_quant unit (acc {}b -> {}b)\n", pe.acc_bits(), c.bits));
        s.push_str(&format!("    sram output ({} bits)\n", pe.out_sram_bits()));
        s.push_str(&format!("    sram select ({} bits)\n", pe.select_sram_bits(c.n_pes)));
        s.push_str("  }\n");
        s
    }

    /// The Fig. 9 specification table as JSON (the `apu figures fig9` output).
    pub fn spec_json(&self) -> Json {
        let m = &self.metrics;
        Json::obj(vec![
            ("technology", Json::str("16 nm TSMC (modeled)")),
            ("chip_mm2", Json::num((m.area_mm2 * 100.0).round() / 100.0)),
            ("precision_bits", Json::Int(self.config.bits as i64)),
            ("onchip_sram_mb", Json::num((m.sram_bits as f64 / 8e6 * 100.0).round() / 100.0)),
            ("n_pes", Json::Int(self.config.n_pes as i64)),
            ("clock_ghz", Json::num(self.config.clock_ghz)),
            ("power_mw", Json::num(m.power_mw.round())),
            ("tops", Json::num((m.tops * 10.0).round() / 10.0)),
            ("tops_per_watt", Json::num((m.tops_per_watt * 10.0).round() / 10.0)),
            ("layer_cycles", Json::Int(m.layer_cycles as i64)),
        ])
    }

    /// Per-component PE report for Figs. 3/4b/10/11.
    pub fn pe_report(&self) -> (crate::hwmodel::PeEnergy, crate::hwmodel::PeArea) {
        let tech = Tech::tsmc16();
        (
            pe_energy_per_cycle(&tech, &self.config.pe_config(), self.config.mode),
            pe_area(&tech, &self.config.pe_config(), self.config.mode),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_instance_matches_fig9() {
        let inst = DesignInstance::generate(GeneratorConfig::default()).unwrap();
        let m = &inst.metrics;
        assert!((m.tops - 16.0).abs() < 0.1);
        assert!((m.power_mw - 440.0).abs() < 60.0);
        assert_eq!(m.layer_cycles, 400);
    }

    #[test]
    fn netlist_mentions_all_blocks() {
        let inst = DesignInstance::generate(GeneratorConfig::default()).unwrap();
        let n = inst.netlist();
        for needle in ["rocket_core", "mux_crossbar", "pe_array [10]", "adder_tree (9 stages", "relu_quant"] {
            assert!(n.contains(needle), "netlist missing {needle}:\n{n}");
        }
    }

    #[test]
    fn temporal_netlist_has_regfile() {
        let cfg = GeneratorConfig { mode: PeMode::Temporal, ..Default::default() };
        let n = DesignInstance::generate(cfg).unwrap().netlist();
        assert!(n.contains("regfile psum"));
        assert!(!n.contains("adder_tree"));
    }

    #[test]
    fn rejects_bad_configs() {
        for cfg in [
            GeneratorConfig { bits: 5, ..Default::default() },
            GeneratorConfig { n_pes: 0, ..Default::default() },
            GeneratorConfig { clock_ghz: 9.0, ..Default::default() },
        ] {
            assert!(DesignInstance::generate(cfg).is_err());
        }
    }

    #[test]
    fn spec_json_is_valid() {
        let inst = DesignInstance::generate(GeneratorConfig::default()).unwrap();
        let j = inst.spec_json();
        assert_eq!(j.get("n_pes").and_then(Json::as_i64), Some(10));
        assert!(Json::parse(&j.pretty()).is_ok());
    }

    #[test]
    fn apu_config_geometry() {
        let inst = DesignInstance::generate(GeneratorConfig::default()).unwrap();
        let ac = inst.apu_config();
        assert_eq!(ac.pe_sram_bits, 640_000);
        assert_eq!(ac.n_pes, 10);
    }
}
