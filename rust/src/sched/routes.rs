//! The routing-schedule algorithm and its verification.

use anyhow::{bail, Result};

/// One routed transfer: at `cycle`, source block `src` broadcasts global
/// activation `act` and destination PE `dst` latches it into input-latch
/// slot `dst_slot`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub cycle: u32,
    pub src: u16,
    pub dst: u16,
    /// Global activation index (position in the producing layer's output).
    pub act: u32,
    /// Destination input-latch slot (= position in the consumer block's
    /// column group — the select-SRAM entry).
    pub dst_slot: u32,
}

/// Per (source, destination) demand: which global activation indices the
/// destination block needs from each source block, with their slots.
#[derive(Debug, Clone)]
pub struct DemandMatrix {
    pub n_src: usize,
    pub n_dst: usize,
    /// `items[s][d]` = (act, dst_slot) pairs to deliver from `s` to `d`.
    pub items: Vec<Vec<Vec<(u32, u32)>>>,
}

impl DemandMatrix {
    pub fn total(&self) -> usize {
        self.items.iter().flatten().map(Vec::len).sum()
    }

    /// Lower bound on schedule length: the busiest source must send all
    /// its items one per cycle; the busiest destination must receive all
    /// its items one per cycle.
    pub fn lower_bound(&self) -> usize {
        let src_max = (0..self.n_src)
            .map(|s| self.items[s].iter().map(Vec::len).sum::<usize>())
            .max()
            .unwrap_or(0);
        let dst_max = (0..self.n_dst)
            .map(|d| (0..self.n_src).map(|s| self.items[s][d].len()).sum::<usize>())
            .max()
            .unwrap_or(0);
        src_max.max(dst_max)
    }
}

/// Build the demand matrix between a producer layer and a consumer layer.
///
/// `producer_groups[s]` lists the global activation indices block `s`
/// produces (the previous layer's `row_groups`, or a chunked split of the
/// network input for the first layer). `consumer_groups[d]` lists the
/// activation indices PE `d` needs, in latch-slot order (the next layer's
/// `col_groups`).
pub fn build_demand(producer_groups: &[Vec<u32>], consumer_groups: &[Vec<u32>]) -> Result<DemandMatrix> {
    let n_src = producer_groups.len();
    let n_dst = consumer_groups.len();
    // owner[act] = source block producing it
    let total: usize = producer_groups.iter().map(Vec::len).sum();
    let mut owner = vec![u16::MAX; total];
    for (s, g) in producer_groups.iter().enumerate() {
        for &a in g {
            let a = a as usize;
            if a >= total {
                bail!("producer activation {a} out of range {total}");
            }
            if owner[a] != u16::MAX {
                bail!("activation {a} produced by two blocks");
            }
            owner[a] = s as u16;
        }
    }
    let mut items = vec![vec![Vec::new(); n_dst]; n_src];
    for (d, g) in consumer_groups.iter().enumerate() {
        for (slot, &a) in g.iter().enumerate() {
            let s = *owner
                .get(a as usize)
                .filter(|&&o| o != u16::MAX)
                .ok_or_else(|| anyhow::anyhow!("consumer needs unproduced activation {a}"))?;
            items[s as usize][d].push((a, slot as u32));
        }
    }
    Ok(DemandMatrix { n_src, n_dst, items })
}

/// The emitted static schedule.
#[derive(Debug, Clone)]
pub struct RouteSchedule {
    pub n_src: usize,
    pub n_dst: usize,
    pub assignments: Vec<Assignment>,
    pub n_cycles: u32,
    /// The demand's lower bound, for congestion accounting.
    pub lower_bound: u32,
}

impl RouteSchedule {
    /// Congestion overhead: 1.0 = perfectly packed schedule.
    pub fn efficiency(&self) -> f64 {
        if self.n_cycles == 0 {
            1.0
        } else {
            self.lower_bound as f64 / self.n_cycles as f64
        }
    }

    /// Verify the paper's invariants: per-cycle 1-to-1 mapping (each source
    /// broadcasts ≤1, each destination latches ≤1) and exactly-once
    /// delivery of every demanded item.
    pub fn verify(&self, demand: &DemandMatrix) -> Result<()> {
        let mut per_cycle_src = vec![vec![false; self.n_src]; self.n_cycles as usize];
        let mut per_cycle_dst = vec![vec![false; self.n_dst]; self.n_cycles as usize];
        let mut delivered: Vec<Vec<Vec<(u32, u32)>>> = vec![vec![Vec::new(); self.n_dst]; self.n_src];
        for a in &self.assignments {
            let (c, s, d) = (a.cycle as usize, a.src as usize, a.dst as usize);
            if c >= self.n_cycles as usize || s >= self.n_src || d >= self.n_dst {
                bail!("assignment out of range: {a:?}");
            }
            if per_cycle_src[c][s] {
                bail!("source {s} broadcasts twice in cycle {c}");
            }
            if per_cycle_dst[c][d] {
                bail!("destination {d} latches twice in cycle {c}");
            }
            per_cycle_src[c][s] = true;
            per_cycle_dst[c][d] = true;
            delivered[s][d].push((a.act, a.dst_slot));
        }
        for s in 0..self.n_src {
            for d in 0..self.n_dst {
                let mut want = demand.items[s][d].clone();
                let mut got = delivered[s][d].clone();
                want.sort_unstable();
                got.sort_unstable();
                if want != got {
                    bail!("delivery mismatch for src {s} → dst {d}: want {} items, got {}", want.len(), got.len());
                }
            }
        }
        Ok(())
    }
}

/// The paper's greedy priority scheduler.
///
/// Every cycle: sort source blocks by remaining pending count (heaviest
/// first — "the block with the highest number is given the priority"),
/// rotate ties round-robin, and let each source claim the still-unclaimed
/// destination for which it holds the most pending items. Guarantees
/// forward progress (any source with pending items and a free matching
/// destination routes), hence deadlock-freedom; the verification pass
/// re-checks every invariant on the emitted schedule.
pub fn schedule_routes(demand: &DemandMatrix) -> Result<RouteSchedule> {
    let n_src = demand.n_src;
    let n_dst = demand.n_dst;
    // Per-pair FIFO queues (consume in slot order for SRAM-friendly reads).
    let mut queues: Vec<Vec<std::collections::VecDeque<(u32, u32)>>> = demand
        .items
        .iter()
        .map(|row| row.iter().map(|v| v.iter().copied().collect()).collect())
        .collect();
    let mut remaining: Vec<usize> = (0..n_src).map(|s| queues[s].iter().map(|q| q.len()).sum()).collect();
    let mut pending_total: usize = remaining.iter().sum();

    let mut assignments = Vec::with_capacity(pending_total);
    let mut cycle: u32 = 0;
    let mut rr_offset: usize = 0; // round-robin rotation of priority ties
    let mut dst_used = vec![u32::MAX; n_dst]; // cycle tag, avoids re-alloc

    while pending_total > 0 {
        // Priority order: heaviest remaining first; ties rotate by rr_offset.
        let mut order: Vec<usize> = (0..n_src).filter(|&s| remaining[s] > 0).collect();
        order.sort_by_key(|&s| (std::cmp::Reverse(remaining[s]), (s + n_src - rr_offset % n_src) % n_src));

        let mut progressed = false;
        for &s in &order {
            // Claim the free destination with the largest pending count.
            let mut best: Option<(usize, usize)> = None; // (count, dst)
            for d in 0..n_dst {
                if dst_used[d] == cycle {
                    continue;
                }
                let c = queues[s][d].len();
                if c > 0 && best.map_or(true, |(bc, _)| c > bc) {
                    best = Some((c, d));
                }
            }
            if let Some((_, d)) = best {
                let (act, dst_slot) = queues[s][d].pop_front().unwrap();
                dst_used[d] = cycle;
                remaining[s] -= 1;
                pending_total -= 1;
                assignments.push(Assignment { cycle, src: s as u16, dst: d as u16, act, dst_slot });
                progressed = true;
            }
        }
        if !progressed {
            bail!("routing deadlock at cycle {cycle}: {pending_total} items stuck");
        }
        cycle += 1;
        rr_offset += 1;
    }

    Ok(RouteSchedule {
        n_src,
        n_dst,
        assignments,
        n_cycles: cycle,
        lower_bound: demand.lower_bound() as u32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::BlockStructure;
    use crate::util::rng::Rng;

    fn chunked(n: usize, k: usize) -> Vec<Vec<u32>> {
        (0..k).map(|g| ((g * n / k) as u32..((g + 1) * n / k) as u32).collect()).collect()
    }

    #[test]
    fn uniform_all_to_all_hits_lower_bound() {
        // k blocks each needing k items, one from every source: a perfect
        // round-robin exists, so the greedy schedule must be optimal.
        let k = 8;
        let producers = chunked(k * k, k);
        // consumer d needs item (s*k + d) from each source s
        let consumers: Vec<Vec<u32>> =
            (0..k).map(|d| (0..k).map(|s| (s * k + d) as u32).collect()).collect();
        let demand = build_demand(&producers, &consumers).unwrap();
        let sched = schedule_routes(&demand).unwrap();
        sched.verify(&demand).unwrap();
        assert_eq!(sched.n_cycles as usize, demand.lower_bound());
        assert_eq!(sched.n_cycles, k as u32);
    }

    #[test]
    fn layer_to_layer_structured_schedule() {
        // Real shape: layer L (nb=5 over 40 outs) feeding layer L+1
        // (nb=5 over 40 ins).
        let mut rng = Rng::new(3);
        let l0 = BlockStructure::random(40, 30, 5, &mut rng).unwrap();
        let l1 = BlockStructure::random(20, 40, 5, &mut rng).unwrap();
        let demand = build_demand(&l0.row_groups, &l1.col_groups).unwrap();
        assert_eq!(demand.total(), 40); // every activation routed once
        let sched = schedule_routes(&demand).unwrap();
        sched.verify(&demand).unwrap();
        assert!(sched.efficiency() > 0.5, "efficiency {}", sched.efficiency());
    }

    #[test]
    fn skewed_demand_still_schedules() {
        // One destination needs everything from one source: length = n.
        let producers = chunked(16, 4);
        let consumers = vec![(0..16).map(|i| i as u32).collect::<Vec<u32>>()];
        let demand = build_demand(&producers, &consumers).unwrap();
        let sched = schedule_routes(&demand).unwrap();
        sched.verify(&demand).unwrap();
        assert_eq!(sched.n_cycles, 16); // dst bottleneck: one latch per cycle
        assert_eq!(sched.lower_bound, 16);
    }

    #[test]
    fn detects_unproduced_activation() {
        let producers = chunked(8, 2);
        let consumers = vec![vec![0, 99]];
        assert!(build_demand(&producers, &consumers).is_err());
    }

    #[test]
    fn detects_double_production() {
        let producers = vec![vec![0, 1], vec![1, 2]];
        let consumers = vec![vec![0]];
        assert!(build_demand(&producers, &consumers).is_err());
    }

    #[test]
    fn verify_catches_conflicts() {
        let producers = chunked(4, 2);
        let consumers = chunked(4, 2);
        let demand = build_demand(&producers, &consumers).unwrap();
        let mut sched = schedule_routes(&demand).unwrap();
        sched.verify(&demand).unwrap();
        // corrupt: move every assignment to cycle 0 → dst conflicts
        for a in &mut sched.assignments {
            a.cycle = 0;
        }
        assert!(sched.verify(&demand).is_err());
    }

    #[test]
    fn empty_demand_is_trivial() {
        let demand = DemandMatrix { n_src: 3, n_dst: 3, items: vec![vec![Vec::new(); 3]; 3] };
        let sched = schedule_routes(&demand).unwrap();
        assert_eq!(sched.n_cycles, 0);
        sched.verify(&demand).unwrap();
    }

    #[test]
    fn random_structures_schedule_near_optimally() {
        // Property-style sweep: random producer/consumer partitions must
        // verify and stay within 1.6× of the lower bound.
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let nb = 2 + rng.usize_below(6);
            let n = nb * (2 + rng.usize_below(10));
            let prod = BlockStructure::random(n, n, nb, &mut rng).unwrap();
            let cons = BlockStructure::random(n, n, nb, &mut rng).unwrap();
            let demand = build_demand(&prod.row_groups, &cons.col_groups).unwrap();
            let sched = schedule_routes(&demand).unwrap();
            sched.verify(&demand).unwrap();
            assert!(
                (sched.n_cycles as usize) <= demand.lower_bound() * 8 / 5 + 2,
                "seed {seed}: {} cycles vs lb {}",
                sched.n_cycles,
                demand.lower_bound()
            );
        }
    }
}
