//! Static routing schedule for the activation shuffle (paper §3.1.2).
//!
//! Between two structured-pruned layers, the activations produced by layer
//! `L`'s blocks (each living in one PE's output SRAM) must be delivered to
//! the PEs computing layer `L+1`, permuted per the mask's column groups.
//! The permutations are known at compile time, so the routes are a static
//! schedule: every cycle each source PE broadcasts one activation on the
//! output-multiplexed crossbar and each destination PE latches at most one
//! — a 1-to-1 mapping per cycle, verified deadlock- and conflict-free.
//!
//! The algorithm is the paper's: sort blocks by pending count, give the
//! heaviest block priority to claim a destination (round-robin tie
//! rotation), emit up to `N` routes per cycle.

pub mod routes;

pub use routes::{build_demand, schedule_routes, Assignment, DemandMatrix, RouteSchedule};
