//! Interconnect design alternatives for the activation shuffle
//! (paper §3.1.2, Figs. 5 and 6).
//!
//! Three implementations of an `N`-activation permutation network are
//! modeled and, for the mux design, functionally implemented:
//!
//! * **Full crossbar** — every input wired to every output; maximally
//!   flexible, but configuration memory grows as `N²` (one-hot crosspoint
//!   state per output).
//! * **Clos multistage** — 3-stage network of `√N`-radix switches; cheaper
//!   crosspoints but needs per-stage routing tables (`≈ 3·N·log₂N` bits)
//!   and a non-blocking route computation.
//! * **Output-multiplexed crossbar (the paper's design)** — each PE
//!   broadcasts on its own wire; each PE's input is one `P:1` mux driven
//!   by a select SRAM written at compile time. Config memory is
//!   `N·log₂P` bits — one to two orders of magnitude below the
//!   alternatives (Fig. 6).

pub mod mux;

pub use mux::MuxCrossbar;

/// Routing-network design points compared in Fig. 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingDesign {
    Crossbar,
    Clos,
    /// Output-multiplexed crossbar with `P` PEs (the paper's design).
    Mux { n_pes: usize },
}

impl RoutingDesign {
    pub fn name(&self) -> &'static str {
        match self {
            RoutingDesign::Crossbar => "crossbar",
            RoutingDesign::Clos => "clos",
            RoutingDesign::Mux { .. } => "mux",
        }
    }

    /// Configuration/schedule memory (bits) needed to route `n` activation
    /// values through the network for one layer (Fig. 6's y-axis).
    pub fn config_bits(&self, n: usize) -> f64 {
        let nf = n as f64;
        match self {
            // One-hot crosspoint state per output column.
            RoutingDesign::Crossbar => nf * nf,
            // 3 stages of √N-radix switches, each switch storing its
            // input→output mapping: 3 · N · log2(N) bits of routing table.
            RoutingDesign::Clos => 3.0 * nf * nf.log2().max(1.0),
            // One select per routed value: log2(P) bits, N values.
            RoutingDesign::Mux { n_pes } => nf * (*n_pes as f64).log2().max(1.0).ceil(),
        }
    }

    /// Crosspoint/switch-hardware cost in minimum-width mux-equivalents
    /// (area proxy used alongside config memory in the DSE).
    pub fn switch_cost(&self, n: usize) -> f64 {
        let nf = n as f64;
        match self {
            RoutingDesign::Crossbar => nf * nf,
            RoutingDesign::Clos => {
                let r = nf.sqrt().ceil();
                3.0 * r * r * r // 3 stages × r switches × r² crosspoints
            }
            RoutingDesign::Mux { n_pes } => {
                let p = *n_pes as f64;
                p * p // P muxes of radix P
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_mux_saves_one_to_two_orders_of_magnitude() {
        // Paper Fig. 6: mux vs multistage and crossbar across data sizes.
        for &n in &[256usize, 1024, 4096] {
            let mux = RoutingDesign::Mux { n_pes: 10 }.config_bits(n);
            let clos = RoutingDesign::Clos.config_bits(n);
            let xbar = RoutingDesign::Crossbar.config_bits(n);
            assert!(clos / mux > 5.0, "n={n}: clos/mux {}", clos / mux);
            assert!(xbar / mux > 60.0, "n={n}: xbar/mux {}", xbar / mux);
            assert!(xbar > clos, "crossbar must be the most expensive");
        }
        // and the gap grows with N (the figure's diverging curves)
        let gap_small = RoutingDesign::Crossbar.config_bits(128) / RoutingDesign::Mux { n_pes: 10 }.config_bits(128);
        let gap_big = RoutingDesign::Crossbar.config_bits(4096) / RoutingDesign::Mux { n_pes: 10 }.config_bits(4096);
        assert!(gap_big > gap_small * 10.0);
    }

    #[test]
    fn switch_cost_ordering() {
        for &n in &[100usize, 1000] {
            let mux = RoutingDesign::Mux { n_pes: 10 }.switch_cost(n);
            let clos = RoutingDesign::Clos.switch_cost(n);
            let xbar = RoutingDesign::Crossbar.switch_cost(n);
            assert!(mux < clos && clos < xbar, "n={n}: {mux} {clos} {xbar}");
        }
    }

    #[test]
    fn names() {
        assert_eq!(RoutingDesign::Crossbar.name(), "crossbar");
        assert_eq!(RoutingDesign::Clos.name(), "clos");
        assert_eq!(RoutingDesign::Mux { n_pes: 4 }.name(), "mux");
    }
}
