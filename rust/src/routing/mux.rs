//! Functional model of the output-multiplexed crossbar (paper Fig. 5).
//!
//! Every cycle each PE broadcasts one value on its dedicated wire; each
//! PE's input mux selects one broadcaster per its select-SRAM entry. The
//! cycle-accurate simulator drives this model with the static schedule
//! emitted by [`crate::sched::schedule_routes`].

use anyhow::{bail, Result};

/// One `P`-port broadcast bus + per-PE select state.
#[derive(Debug, Clone)]
pub struct MuxCrossbar {
    n_pes: usize,
    /// Broadcast wires, one per PE (None = idle this cycle).
    bus: Vec<Option<f32>>,
    /// Select per destination PE (None = latch nothing this cycle).
    selects: Vec<Option<u16>>,
    /// Cumulative routed-value count (for energy accounting).
    routed: u64,
}

impl MuxCrossbar {
    pub fn new(n_pes: usize) -> MuxCrossbar {
        MuxCrossbar { n_pes, bus: vec![None; n_pes], selects: vec![None; n_pes], routed: 0 }
    }

    pub fn n_pes(&self) -> usize {
        self.n_pes
    }

    /// Begin a cycle: clear bus and selects.
    pub fn begin_cycle(&mut self) {
        self.bus.fill(None);
        self.selects.fill(None);
    }

    /// Source PE `src` drives its broadcast wire. One drive per wire per
    /// cycle (the hardware has a single driver per wire).
    pub fn broadcast(&mut self, src: usize, value: f32) -> Result<()> {
        if src >= self.n_pes {
            bail!("broadcast from PE {src} out of range");
        }
        if self.bus[src].is_some() {
            bail!("PE {src} drove its wire twice in one cycle");
        }
        self.bus[src] = Some(value);
        Ok(())
    }

    /// Destination PE `dst` sets its mux select to listen to `src`.
    pub fn select(&mut self, dst: usize, src: usize) -> Result<()> {
        if dst >= self.n_pes || src >= self.n_pes {
            bail!("select {dst}←{src} out of range");
        }
        if self.selects[dst].is_some() {
            bail!("PE {dst} set its select twice in one cycle");
        }
        self.selects[dst] = Some(src as u16);
        Ok(())
    }

    /// End a cycle: resolve each destination's latched value.
    /// Returns `(dst, value)` for every destination that selected a
    /// driven wire; selecting an undriven wire is a schedule bug.
    pub fn end_cycle(&mut self) -> Result<Vec<(usize, f32)>> {
        let mut latched = Vec::new();
        for dst in 0..self.n_pes {
            if let Some(src) = self.selects[dst] {
                match self.bus[src as usize] {
                    Some(v) => latched.push((dst, v)),
                    None => bail!("PE {dst} selected idle wire {src}"),
                }
            }
        }
        self.routed += latched.len() as u64;
        Ok(latched)
    }

    /// Total values routed since construction.
    pub fn routed_count(&self) -> u64 {
        self.routed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_a_permutation_cycle() {
        let mut xb = MuxCrossbar::new(4);
        xb.begin_cycle();
        for src in 0..4 {
            xb.broadcast(src, src as f32 * 10.0).unwrap();
            xb.select((src + 1) % 4, src).unwrap();
        }
        let mut got = xb.end_cycle().unwrap();
        got.sort_by_key(|&(d, _)| d);
        assert_eq!(got, vec![(0, 30.0), (1, 0.0), (2, 10.0), (3, 20.0)]);
        assert_eq!(xb.routed_count(), 4);
    }

    #[test]
    fn rejects_double_drive_and_double_select() {
        let mut xb = MuxCrossbar::new(2);
        xb.begin_cycle();
        xb.broadcast(0, 1.0).unwrap();
        assert!(xb.broadcast(0, 2.0).is_err());
        xb.select(1, 0).unwrap();
        assert!(xb.select(1, 0).is_err());
    }

    #[test]
    fn rejects_idle_wire_select() {
        let mut xb = MuxCrossbar::new(2);
        xb.begin_cycle();
        xb.select(0, 1).unwrap(); // wire 1 never driven
        assert!(xb.end_cycle().is_err());
    }

    #[test]
    fn idle_cycle_is_fine() {
        let mut xb = MuxCrossbar::new(3);
        xb.begin_cycle();
        assert!(xb.end_cycle().unwrap().is_empty());
    }

    #[test]
    fn bounds_checked() {
        let mut xb = MuxCrossbar::new(2);
        xb.begin_cycle();
        assert!(xb.broadcast(2, 0.0).is_err());
        assert!(xb.select(0, 2).is_err());
        assert!(xb.select(2, 0).is_err());
    }
}
