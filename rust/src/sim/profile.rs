//! Per-layer, per-phase cycle/energy profile of a simulation.
//!
//! [`SimProfile`] is the observability view of [`super::SimStats`]: every
//! charge the simulator books (route, compute, host op, weight stream)
//! is mirrored here as a [`PhaseRecord`] keyed by the active layer id,
//! *and* accumulated into an internal `SimStats` by the exact same
//! sequence of additions the live stats receive. Because f64 addition is
//! deterministic for a fixed order of operands, the profile's totals are
//! bitwise identical to the machine's stats — [`SimProfile::check_against`]
//! asserts this, so a profile that drifts from the ground truth is a bug,
//! not a rounding artifact. (`load_pj` is excluded: it is charged at
//! `Apu::load`, outside any profiled run.)
//!
//! Attribution caveat: host ops are keyed by the most recent
//! `ConfigLayer` context. Ops emitted before the first spatial layer
//! (e.g. a conv front-end's input Gather) land on `layer: None`, shown
//! as `(ingress)`; pooling host ops ride the preceding layer's id. The
//! per-op breakdown keeps those costs visible by kind regardless of
//! layer attribution.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::apu::SimStats;
use crate::obs::trace::{chrome_trace_json, TraceEvent, PID_SIM};
use crate::util::json::Json;
use crate::util::table::{eng, Table};

/// Which accounting bucket a charge lands in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    Route,
    Compute,
    Host,
    Stream,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Route => "route",
            Phase::Compute => "compute",
            Phase::Host => "host",
            Phase::Stream => "stream",
        }
    }
}

/// One booked charge: `cycles`/`pj`/`macs` attributed to `layer` starting
/// at machine cycle `start_cycle` (cumulative across runs).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseRecord {
    /// Active layer id, `None` before the first `ConfigLayer` (ingress
    /// host ops).
    pub layer: Option<u16>,
    pub phase: Phase,
    /// Operation kind: `"route"`, `"compute"`, `"weight-stream"`, or the
    /// host-op name (`"relu"`, `"maxpool"`, `"fold-add"`, `"gather"`,
    /// `"quantize"`, `"dense"`).
    pub detail: &'static str,
    pub start_cycle: u64,
    pub cycles: u64,
    pub pj: f64,
    pub macs: u64,
}

/// Recorded profile of one or more `Apu::run` calls.
#[derive(Debug, Clone, Default)]
pub struct SimProfile {
    /// Mirror of the machine's stats, accumulated charge-by-charge in the
    /// identical order (see module docs).
    stats: SimStats,
    records: Vec<PhaseRecord>,
}

impl SimProfile {
    /// Profile totals — bitwise equal to the machine's [`SimStats`]
    /// except `load_pj`/fields charged outside `run`.
    pub fn totals(&self) -> &SimStats {
        &self.stats
    }

    pub fn records(&self) -> &[PhaseRecord] {
        &self.records
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub(crate) fn charge(
        &mut self,
        layer: Option<u16>,
        phase: Phase,
        detail: &'static str,
        start_cycle: u64,
        cycles: u64,
        pj: f64,
        macs: u64,
    ) {
        match phase {
            Phase::Route => {
                self.stats.route_cycles += cycles;
                self.stats.route_pj += pj;
            }
            Phase::Compute => {
                self.stats.compute_cycles += cycles;
                self.stats.compute_pj += pj;
            }
            Phase::Host => {
                self.stats.host_cycles += cycles;
                self.stats.host_pj += pj;
            }
            Phase::Stream => {
                self.stats.stream_cycles += cycles;
                self.stats.stream_pj += pj;
            }
        }
        self.stats.macs += macs;
        self.records.push(PhaseRecord { layer, phase, detail, start_cycle, cycles, pj, macs });
    }

    pub(crate) fn count_inference(&mut self) {
        self.stats.inferences += 1;
    }

    /// Aggregate records per layer id (insertion order of charges within
    /// a layer is preserved in the aggregation).
    pub fn by_layer(&self) -> BTreeMap<Option<u16>, SimStats> {
        let mut out: BTreeMap<Option<u16>, SimStats> = BTreeMap::new();
        for r in &self.records {
            let agg = out.entry(r.layer).or_default();
            match r.phase {
                Phase::Route => {
                    agg.route_cycles += r.cycles;
                    agg.route_pj += r.pj;
                }
                Phase::Compute => {
                    agg.compute_cycles += r.cycles;
                    agg.compute_pj += r.pj;
                }
                Phase::Host => {
                    agg.host_cycles += r.cycles;
                    agg.host_pj += r.pj;
                }
                Phase::Stream => {
                    agg.stream_cycles += r.cycles;
                    agg.stream_pj += r.pj;
                }
            }
            agg.macs += r.macs;
        }
        out
    }

    /// Aggregate cycles/pJ per operation kind (`detail`), across layers.
    pub fn detail_totals(&self) -> BTreeMap<&'static str, (u64, f64)> {
        let mut out: BTreeMap<&'static str, (u64, f64)> = BTreeMap::new();
        for r in &self.records {
            let e = out.entry(r.detail).or_insert((0, 0.0));
            e.0 += r.cycles;
            e.1 += r.pj;
        }
        out
    }

    /// Assert the mirrored totals equal the machine's stats exactly
    /// (bitwise on the f64 energy fields). `load_pj` is excluded — it is
    /// charged at program load, before profiling sees any run.
    pub fn check_against(&self, stats: &SimStats) -> Result<()> {
        let p = &self.stats;
        let ints: [(&str, u64, u64); 6] = [
            ("route_cycles", p.route_cycles, stats.route_cycles),
            ("compute_cycles", p.compute_cycles, stats.compute_cycles),
            ("host_cycles", p.host_cycles, stats.host_cycles),
            ("stream_cycles", p.stream_cycles, stats.stream_cycles),
            ("macs", p.macs, stats.macs),
            ("inferences", p.inferences, stats.inferences),
        ];
        for (name, a, b) in ints {
            if a != b {
                bail!("profile {name} = {a} but SimStats has {b}");
            }
        }
        let floats: [(&str, f64, f64); 4] = [
            ("route_pj", p.route_pj, stats.route_pj),
            ("compute_pj", p.compute_pj, stats.compute_pj),
            ("host_pj", p.host_pj, stats.host_pj),
            ("stream_pj", p.stream_pj, stats.stream_pj),
        ];
        for (name, a, b) in floats {
            if a.to_bits() != b.to_bits() {
                bail!("profile {name} = {a} but SimStats has {b} (not bitwise equal)");
            }
        }
        Ok(())
    }

    /// Render the per-layer breakdown (and a per-op-kind appendix) as
    /// aligned console tables. `layer_names` indexes by layer id (the
    /// compiler's `NetworkCost` layer order); missing names fall back to
    /// `layer<N>`.
    pub fn table(&self, layer_names: &[String]) -> String {
        let mut t = Table::new(&[
            "layer", "route", "compute", "host", "stream", "cycles", "share", "pJ", "MACs",
        ]);
        let grand = self.stats.total_cycles();
        for (layer, agg) in self.by_layer() {
            let name = match layer {
                None => "(ingress)".to_string(),
                Some(l) => layer_names
                    .get(l as usize)
                    .cloned()
                    .unwrap_or_else(|| format!("layer{l}")),
            };
            let share =
                if grand > 0 { 100.0 * agg.total_cycles() as f64 / grand as f64 } else { 0.0 };
            t.row(&[
                name,
                agg.route_cycles.to_string(),
                agg.compute_cycles.to_string(),
                agg.host_cycles.to_string(),
                agg.stream_cycles.to_string(),
                agg.total_cycles().to_string(),
                format!("{share:.1}%"),
                eng(agg.total_pj()),
                agg.macs.to_string(),
            ]);
        }
        t.row(&[
            "TOTAL".to_string(),
            self.stats.route_cycles.to_string(),
            self.stats.compute_cycles.to_string(),
            self.stats.host_cycles.to_string(),
            self.stats.stream_cycles.to_string(),
            grand.to_string(),
            "100.0%".to_string(),
            eng(self.stats.total_pj()),
            self.stats.macs.to_string(),
        ]);
        let mut out = t.render();
        let details = self.detail_totals();
        if !details.is_empty() {
            out.push_str("\nper-op breakdown:\n");
            let mut d = Table::new(&["op", "cycles", "pJ"]);
            for (detail, (cycles, pj)) in details {
                d.row(&[detail.to_string(), cycles.to_string(), eng(pj)]);
            }
            out.push_str(&d.render());
        }
        out
    }

    /// Convert the cycle records to Chrome trace events on the simulator
    /// lane ([`PID_SIM`]): one thread row per layer (`tid = layer + 1`,
    /// ingress on `tid 0`), cycle timestamps converted to µs at
    /// `clock_ghz` (1 GHz assumed if the clock is invalid).
    pub fn trace_events(&self, clock_ghz: f64) -> Vec<TraceEvent> {
        let clk = if clock_ghz > 0.0 && clock_ghz.is_finite() { clock_ghz } else { 1.0 };
        let to_us = |cyc: u64| cyc as f64 / (clk * 1e3);
        self.records
            .iter()
            .map(|r| TraceEvent {
                name: r.detail.to_string(),
                cat: r.phase.name().to_string(),
                pid: PID_SIM,
                tid: r.layer.map(|l| l as u64 + 1).unwrap_or(0),
                ts_us: to_us(r.start_cycle),
                dur_us: to_us(r.cycles),
                args: vec![
                    (
                        "layer".to_string(),
                        match r.layer {
                            Some(l) => Json::Int(l as i64),
                            None => Json::Null,
                        },
                    ),
                    ("cycles".to_string(), Json::Int(r.cycles as i64)),
                    ("pj".to_string(), Json::num(r.pj)),
                    ("macs".to_string(), Json::Int(r.macs as i64)),
                ],
            })
            .collect()
    }

    pub fn chrome_trace(&self, clock_ghz: f64) -> Json {
        chrome_trace_json(&self.trace_events(clock_ghz))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SimProfile {
        let mut p = SimProfile::default();
        p.charge(None, Phase::Host, "gather", 0, 10, 1.5, 0);
        p.charge(Some(0), Phase::Route, "route", 10, 4, 0.25, 0);
        p.charge(Some(0), Phase::Compute, "compute", 14, 8, 2.0, 64);
        p.charge(Some(1), Phase::Stream, "weight-stream", 22, 3, 0.5, 0);
        p.charge(Some(1), Phase::Compute, "compute", 25, 6, 1.25, 32);
        p.count_inference();
        p
    }

    #[test]
    fn totals_mirror_charges() {
        let p = sample();
        let t = p.totals();
        assert_eq!(t.route_cycles, 4);
        assert_eq!(t.compute_cycles, 14);
        assert_eq!(t.host_cycles, 10);
        assert_eq!(t.stream_cycles, 3);
        assert_eq!(t.macs, 96);
        assert_eq!(t.inferences, 1);
        assert_eq!(t.total_cycles(), 31);
    }

    #[test]
    fn check_against_is_exact() {
        let p = sample();
        let mut stats = p.totals().clone();
        assert!(p.check_against(&stats).is_ok());
        // load_pj differences are ignored (charged outside run)
        stats.load_pj += 123.0;
        assert!(p.check_against(&stats).is_ok());
        stats.compute_pj += 1e-12;
        let err = p.check_against(&stats).unwrap_err();
        assert!(format!("{err:#}").contains("compute_pj"), "{err:#}");
    }

    #[test]
    fn by_layer_partitions_every_charge() {
        let p = sample();
        let by = p.by_layer();
        assert_eq!(by.len(), 3);
        assert_eq!(by[&None].host_cycles, 10);
        assert_eq!(by[&Some(0)].compute_cycles, 8);
        assert_eq!(by[&Some(0)].macs, 64);
        assert_eq!(by[&Some(1)].stream_cycles, 3);
        let cycle_sum: u64 = by.values().map(|a| a.total_cycles()).sum();
        assert_eq!(cycle_sum, p.totals().total_cycles());
        let pj_sum: f64 = by.values().map(|a| a.total_pj()).sum();
        assert!((pj_sum - p.totals().total_pj()).abs() < 1e-9);
    }

    #[test]
    fn detail_totals_key_by_op_kind() {
        let p = sample();
        let d = p.detail_totals();
        assert_eq!(d["compute"], (14, 3.25));
        assert_eq!(d["gather"], (10, 1.5));
        assert_eq!(d["weight-stream"], (3, 0.5));
    }

    #[test]
    fn table_lists_layers_and_total() {
        let p = sample();
        let out = p.table(&["fc1".to_string()]);
        assert!(out.contains("(ingress)"));
        assert!(out.contains("fc1"));
        assert!(out.contains("layer1")); // fallback name for unnamed layer 1
        assert!(out.contains("TOTAL"));
        assert!(out.contains("per-op breakdown"));
    }

    #[test]
    fn trace_events_convert_cycles_to_us() {
        let p = sample();
        let evs = p.trace_events(1.0); // 1 GHz: 1000 cycles per µs
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].tid, 0); // ingress lane
        assert_eq!(evs[1].tid, 1); // layer 0 lane
        assert!((evs[1].ts_us - 0.010).abs() < 1e-12);
        assert!((evs[2].dur_us - 0.008).abs() < 1e-12);
        // timestamps non-decreasing in record order (cycles are serial)
        assert!(evs.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        // invalid clock falls back instead of producing NaN
        let evs0 = p.trace_events(0.0);
        assert!(evs0.iter().all(|e| e.ts_us.is_finite()));
    }

    #[test]
    fn chrome_trace_round_trips() {
        let p = sample();
        let text = p.chrome_trace(1.0).pretty();
        let back = Json::parse(&text).unwrap();
        let evs = back.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].get("cat").and_then(Json::as_str), Some("host"));
        assert_eq!(evs[0].path("args/layer"), Some(&Json::Null));
    }
}
