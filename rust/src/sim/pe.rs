//! One processing element: the Fig. 4a datapath as a functional unit.
//!
//! State mirrors the silicon: weight SRAM (INT-k codes), input activation
//! latch, output SRAM, dequant scales, and the layer geometry. The
//! `compute_row` step is the spatial datapath — `bw` multipliers, the
//! mixed-precision adder tree (a single pass here; order-insensitive
//! integer sum), bias add, ReLU, and the end-of-tree quantizer. The
//! integer accumulation is exact (i32 codes × f32 grid inputs carried in
//! f32 products summed in f64 ≡ the tree's widening adders), so the PE
//! reproduces `pruning::PackedLayer::forward` bit-for-bit.

use anyhow::{bail, Result};

use crate::pruning::Quantizer;

/// Runtime state of one PE.
#[derive(Debug, Clone)]
pub struct PeUnit {
    /// Weight SRAM capacity, bits (generator parameter).
    pub sram_capacity_bits: usize,
    // -- per-layer configuration --
    bh: usize,
    bw: usize,
    bits: u32,
    relu: bool,
    /// INT-k weight codes, row-major `bh × bw`.
    codes: Vec<i8>,
    /// Dequant scale for this block's weights.
    w_scale: f32,
    /// Output quantizer scale (end of adder tree).
    out_scale: f32,
    bias: Vec<f32>,
    /// Input activation latch (one value per column slot).
    latch: Vec<f32>,
    latch_filled: Vec<bool>,
    /// Output SRAM: one activation per computed row.
    out: Vec<f32>,
    /// Lifetime rows computed (utilization accounting — survives
    /// `configure`, cleared only when the PE is rebuilt).
    rows_computed: u64,
}

impl PeUnit {
    pub fn new(sram_capacity_bits: usize) -> PeUnit {
        PeUnit {
            sram_capacity_bits,
            bh: 0,
            bw: 0,
            bits: 4,
            relu: true,
            codes: Vec::new(),
            w_scale: 1.0,
            out_scale: 1.0,
            bias: Vec::new(),
            latch: Vec::new(),
            latch_filled: Vec::new(),
            out: Vec::new(),
            rows_computed: 0,
        }
    }

    /// Configure layer geometry (ConfigLayer), clearing transient state.
    pub fn configure(&mut self, bh: usize, bw: usize, bits: u32, relu: bool) -> Result<()> {
        let need = bh * bw * bits as usize;
        if need > self.sram_capacity_bits {
            bail!("block {bh}x{bw}x{bits}b needs {need} bits > PE SRAM {}", self.sram_capacity_bits);
        }
        self.bh = bh;
        self.bw = bw;
        self.bits = bits;
        self.relu = relu;
        self.codes.clear();
        self.bias.clear();
        // clear+resize keeps each buffer's capacity across layers —
        // reconfiguring never reallocates once warmed up
        self.latch.clear();
        self.latch.resize(bw, 0.0);
        self.latch_filled.clear();
        self.latch_filled.resize(bw, false);
        self.out.clear();
        self.out.resize(bh, 0.0);
        Ok(())
    }

    pub fn load_weights(&mut self, codes: &[i8]) -> Result<()> {
        if codes.len() != self.bh * self.bw {
            bail!("weight segment {} != {}x{}", codes.len(), self.bh, self.bw);
        }
        let q = Quantizer::qmax(self.bits);
        if let Some(&c) = codes.iter().find(|&&c| (c as i32).abs() > q) {
            bail!("weight code {c} exceeds INT{} range", self.bits);
        }
        self.codes.clear();
        self.codes.extend_from_slice(codes);
        Ok(())
    }

    pub fn load_bias(&mut self, bias: &[f32]) -> Result<()> {
        if bias.len() != self.bh {
            bail!("bias segment {} != bh {}", bias.len(), self.bh);
        }
        self.bias.clear();
        self.bias.extend_from_slice(bias);
        Ok(())
    }

    /// Set dequant scales. `out_scale == 0.0` bypasses the output
    /// quantizer (full-precision logit heads).
    pub fn set_scales(&mut self, w_scale: f32, out_scale: f32) -> Result<()> {
        if w_scale <= 0.0 || out_scale < 0.0 {
            bail!("bad scales: w={w_scale} out={out_scale}");
        }
        self.w_scale = w_scale;
        self.out_scale = out_scale;
        Ok(())
    }

    /// Latch one routed activation into slot `slot` (routing phase).
    pub fn latch_input(&mut self, slot: usize, value: f32) -> Result<()> {
        if slot >= self.bw {
            bail!("latch slot {slot} out of range {}", self.bw);
        }
        if self.latch_filled[slot] {
            bail!("latch slot {slot} written twice this layer");
        }
        self.latch[slot] = value;
        self.latch_filled[slot] = true;
        Ok(())
    }

    /// All input slots latched? (the spatial mode's precondition: "all the
    /// input activations related to a particular output value need to be
    /// available prior to the computation").
    pub fn inputs_ready(&self) -> bool {
        self.latch_filled.iter().all(|&f| f)
    }

    /// One spatial cycle: read weight row `row`, multiply-reduce against
    /// the latch, bias + ReLU + quantize, write the output SRAM.
    pub fn compute_row(&mut self, row: usize) -> Result<f32> {
        if row >= self.bh {
            bail!("row {row} out of range {}", self.bh);
        }
        if self.codes.is_empty() {
            bail!("compute before weights loaded");
        }
        if !self.inputs_ready() {
            bail!("compute with {} unfilled latch slots", self.latch_filled.iter().filter(|&&f| !f).count());
        }
        let base = row * self.bw;
        // Multiplier array + adder tree: integer codes × grid activations.
        // f64 accumulation models the widening tree exactly (no rounding);
        // the zip form drops per-element bounds checks (§Perf iter 2).
        let acc: f64 = self.codes[base..base + self.bw]
            .iter()
            .zip(&self.latch)
            .map(|(&c, &a)| c as f64 * a as f64)
            .sum();
        let mut o = acc as f32 * self.w_scale + self.bias.get(row).copied().unwrap_or(0.0);
        if self.relu {
            o = o.max(0.0);
        }
        if self.out_scale > 0.0 {
            o = Quantizer::new(self.bits, self.out_scale).fake(o);
        }
        self.out[row] = o;
        self.rows_computed += 1;
        Ok(o)
    }

    /// Lifetime rows computed by this PE (per-PE utilization metric).
    pub fn rows_computed(&self) -> u64 {
        self.rows_computed
    }

    /// Reset latch-filled flags for the next layer (outputs persist — they
    /// are the next routing phase's sources).
    pub fn clear_latch(&mut self) {
        self.latch_filled.fill(false);
    }

    pub fn output(&self, row: usize) -> Option<f32> {
        self.out.get(row).copied()
    }

    pub fn outputs(&self) -> &[f32] {
        &self.out
    }

    pub fn geometry(&self) -> (usize, usize, u32, bool) {
        (self.bh, self.bw, self.bits, self.relu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ready_pe() -> PeUnit {
        let mut pe = PeUnit::new(1 << 20);
        pe.configure(2, 3, 4, true).unwrap();
        pe.load_weights(&[1, -2, 3, 0, 7, -7]).unwrap();
        pe.load_bias(&[0.5, -0.25]).unwrap();
        pe.set_scales(0.5, 0.25).unwrap();
        for (slot, v) in [(0usize, 1.0f32), (1, -1.0), (2, 0.5)] {
            pe.latch_input(slot, v).unwrap();
        }
        pe
    }

    #[test]
    fn computes_expected_values() {
        let mut pe = ready_pe();
        // row 0: (1*1 + -2*-1 + 3*0.5) * 0.5 + 0.5 = 4.5*0.5+0.5 = 2.75
        // quant(2.75 / 0.25 = 11 -> clamp 7) = 1.75
        assert_eq!(pe.compute_row(0).unwrap(), 1.75);
        // row 1: (0 + 7*-1 + -7*0.5)*0.5 - 0.25 = -10.5*0.5-0.25 = -5.5 -> relu 0
        assert_eq!(pe.compute_row(1).unwrap(), 0.0);
        assert_eq!(pe.outputs(), &[1.75, 0.0]);
    }

    #[test]
    fn matches_packed_layer_reference() {
        use crate::pruning::{BlockStructure, PackedLayer};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        let s = BlockStructure::random(12, 18, 3, &mut rng).unwrap();
        let w: Vec<f32> = (0..12 * 18).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..12).map(|_| rng.normal() * 0.1).collect();
        let out_scale: Vec<f32> = (0..3).map(|_| 0.1 + rng.f64() as f32).collect();
        let packed = PackedLayer::quantize_from(s.clone(), 4, &w, &bias, out_scale.clone(), true).unwrap();
        let a: Vec<f32> = (0..18).map(|_| rng.normal()).collect();
        let want = packed.forward(&a).unwrap();

        for g in 0..3 {
            let mut pe = PeUnit::new(1 << 20);
            pe.configure(s.bh(), s.bw(), 4, true).unwrap();
            pe.load_weights(&packed.codes[g]).unwrap();
            pe.load_bias(&packed.bias[g]).unwrap();
            pe.set_scales(packed.w_scale[g], out_scale[g]).unwrap();
            for (slot, &c) in s.col_groups[g].iter().enumerate() {
                pe.latch_input(slot, a[c as usize]).unwrap();
            }
            for (i, &r) in s.row_groups[g].iter().enumerate() {
                let got = pe.compute_row(i).unwrap();
                assert_eq!(got, want[r as usize], "block {g} row {i}");
            }
        }
    }

    #[test]
    fn enforces_capacity() {
        let mut pe = PeUnit::new(100);
        assert!(pe.configure(10, 10, 4, true).is_err());
        assert!(pe.configure(5, 5, 4, true).is_ok());
    }

    #[test]
    fn rejects_out_of_range_codes() {
        let mut pe = PeUnit::new(1 << 10);
        pe.configure(1, 2, 4, false).unwrap();
        assert!(pe.load_weights(&[8, 0]).is_err());
        assert!(pe.load_weights(&[7, -7]).is_ok());
    }

    #[test]
    fn requires_full_latch() {
        let mut pe = PeUnit::new(1 << 10);
        pe.configure(1, 2, 4, false).unwrap();
        pe.load_weights(&[1, 1]).unwrap();
        pe.load_bias(&[0.0]).unwrap();
        pe.latch_input(0, 1.0).unwrap();
        assert!(pe.compute_row(0).is_err()); // slot 1 missing
        pe.latch_input(1, 1.0).unwrap();
        assert!(pe.compute_row(0).is_ok());
    }

    #[test]
    fn rows_computed_counts_across_configures() {
        let mut pe = ready_pe();
        assert_eq!(pe.rows_computed(), 0);
        pe.compute_row(0).unwrap();
        pe.compute_row(1).unwrap();
        assert_eq!(pe.rows_computed(), 2);
        // reconfiguring starts a new layer but keeps the lifetime count
        pe.configure(1, 1, 4, false).unwrap();
        pe.load_weights(&[1]).unwrap();
        pe.load_bias(&[0.0]).unwrap();
        pe.latch_input(0, 1.0).unwrap();
        pe.compute_row(0).unwrap();
        assert_eq!(pe.rows_computed(), 3);
    }

    #[test]
    fn double_latch_rejected_until_cleared() {
        let mut pe = PeUnit::new(1 << 10);
        pe.configure(1, 1, 4, false).unwrap();
        pe.latch_input(0, 1.0).unwrap();
        assert!(pe.latch_input(0, 2.0).is_err());
        pe.clear_latch();
        assert!(pe.latch_input(0, 2.0).is_ok());
    }
}
