//! Pre-decoded execution plans: the load-time compile step behind
//! [`super::Apu`]'s hot path.
//!
//! [`ExecPlan::build`] runs a *symbolic* pass over the program — the same
//! control flow as the reference interpreter (`Apu::run_reference`), but
//! over buffer lengths and ownership tags instead of values. Everything
//! the interpreter validates per run (segment types and shapes, crossbar
//! drive/select conflicts, latch coverage, scatter ownership,
//! partial-buffer completeness, the final output length) is checked once
//! here; everything it decodes per run (routes, permutations, weight
//! codes, biases, scales, host-op parameters) is resolved into a flat
//! [`ExecStep`] list the executor replays with no per-run decoding or
//! checks.
//!
//! Because every cycle/energy charge in the simulator depends only on
//! program structure — never on activation values — the builder also
//! records the exact charge sequence one inference books as a
//! [`TapeEntry`] tape, computed with the interpreter's own f64
//! expressions in the interpreter's order. Replaying the tape per
//! inference produces `SimStats`/`SimProfile` accumulations bitwise
//! identical to the interpreter's.
//!
//! The builder is deliberately conservative: any program shape it does
//! not recognize (including every shape the interpreter would reject at
//! run time) makes `build` fail, and `Apu::load` falls back to the
//! reference interpreter for that program — behavior, including error
//! messages and their timing, stays exactly what it always was.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use super::apu::{host_maxpool, weight_residency, ApuConfig};
use super::profile::Phase;
use crate::hwmodel::{pe_energy_per_cycle, PeConfig, PeMode, Tech};
use crate::isa::{HostOpKind, Insn, Program};
use crate::pruning::Quantizer;

/// One charge the interpreter would book for a single inference,
/// replayed verbatim through `Apu::charge_at` (all-zero charges are
/// elided at build time, mirroring the live `charge` early-out).
#[derive(Debug, Clone)]
pub(crate) struct TapeEntry {
    pub layer: Option<u16>,
    pub phase: Phase,
    pub detail: &'static str,
    pub cycles: u64,
    pub pj: f64,
    pub macs: u64,
}

/// One latch write of the routing phase: committed activation `act`
/// lands in flattened latch slot `dst` (= `pe * bw + slot`).
#[derive(Debug, Clone, Copy)]
pub(crate) struct RouteMove {
    pub act: u32,
    pub dst: u32,
}

#[derive(Debug, Clone, Copy)]
pub(crate) enum ScatterTarget {
    /// The layer's pending buffer (`buf == 0`).
    Pending,
    /// Named partial-sum buffer, densely remapped to a scratch slot.
    Partial(usize),
}

#[derive(Debug, Clone)]
pub(crate) struct ScatterExec {
    pub target: ScatterTarget,
    /// First scatter into this incarnation of the buffer: zero-fill it.
    pub init: bool,
    pub dout: usize,
    /// `perm[g*bh + i]` = global output index of PE g's row i.
    pub perm: Vec<u32>,
}

/// Per-PE decoded state for one wave (weight codes, bias, scales applied
/// from the plan image — no per-run segment decode or range checks).
#[derive(Debug, Clone)]
pub(crate) struct WavePe {
    pub codes: Vec<i8>,
    /// May be shorter than `bh` (column tiles carry no bias); missing
    /// rows read as 0.0, same as the PE datapath.
    pub bias: Vec<f32>,
    pub w_scale: f32,
    /// `None` bypasses the output quantizer (`out_scale == 0`).
    pub quant: Option<Quantizer>,
}

/// One ConfigLayer wave: route moves, the MAC phase, and its scatters.
#[derive(Debug, Clone)]
pub(crate) struct WaveExec {
    pub nb: usize,
    pub bh: usize,
    pub bw: usize,
    pub relu: bool,
    pub pes: Vec<WavePe>,
    pub moves: Vec<RouteMove>,
    pub scatters: Vec<ScatterExec>,
}

/// Pre-decoded host-core op (parameters resolved at plan time).
#[derive(Debug, Clone)]
pub(crate) enum HostStep {
    Relu,
    Quantize(Quantizer),
    MaxPool { h: usize, w: usize, c: usize, win: usize, stride: usize },
    /// Fold partial-sum scratch slot into the activation stream.
    FoldAdd(usize),
    /// Gather indices; `-1` = implicit zero (padded conv planes).
    Gather(Vec<i64>),
    Dense { w: Vec<f32>, b: Vec<f32>, din: usize, relu: bool },
}

#[derive(Debug, Clone)]
pub(crate) enum ExecStep {
    /// Commit pending wave scatters into the visible stream (emitted
    /// only where the pending buffer is provably non-empty).
    Commit,
    Wave(Box<WaveExec>),
    Host(HostStep),
}

/// Per-inference value state of one planned stream. A batch keeps one
/// per element; buffers are cleared between runs, never reallocated.
#[derive(Debug, Default)]
pub(crate) struct StreamState {
    pub acts: Vec<f32>,
    pub pending: Vec<f32>,
    pub partial: Vec<Vec<f32>>,
}

/// Flat latch/output scratch shared by all streams (reset per wave).
#[derive(Debug, Default)]
pub(crate) struct WaveScratch {
    pub latch: Vec<f32>,
    pub out: Vec<f32>,
}

/// A program compiled for repeated execution: flat steps + charge tape.
/// Plans are immutable once built and carry no per-run state, so one
/// plan can back any number of [`super::Apu`] instances concurrently
/// (shared via [`Arc`] through the process-wide cache below).
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub(crate) steps: Vec<ExecStep>,
    pub(crate) tape: Vec<TapeEntry>,
    pub(crate) n_partial_slots: usize,
    /// The cache key this plan was built under: the program's content
    /// fingerprint plus the machine config. [`super::Apu::load_with_plan`]
    /// verifies a caller-provided plan against the program/machine it is
    /// being loaded onto, so a mismatched share fails loudly at load
    /// instead of mis-executing.
    pub(crate) key: PlanKey,
}

impl ExecPlan {
    /// The content fingerprint of the program this plan executes.
    pub fn fingerprint(&self) -> u64 {
        self.key.fingerprint
    }

    /// The ingress quantizer: the host `Quantize` every compiled program
    /// opens with, applied to the raw input before anything else. The
    /// result cache (`coordinator::cache`) keys requests on this grid so
    /// inputs that collapse to the same codes share one entry; plans
    /// that do not start with a quantize step return `None`.
    pub fn input_quantizer(&self) -> Option<Quantizer> {
        match self.steps.first() {
            Some(ExecStep::Host(HostStep::Quantize(q))) => Some(*q),
            _ => None,
        }
    }

    /// Compile `program` (already `validate()`d) into an execution plan,
    /// or fail if the program's shape is unsupported / would error at
    /// run time — the caller then falls back to the interpreter.
    pub(crate) fn build(
        program: &Program,
        cfg: &ApuConfig,
        tech: &Tech,
        streamed: bool,
        key: PlanKey,
    ) -> Result<ExecPlan> {
        Builder {
            key,
            program,
            cfg,
            tech,
            streamed,
            steps: Vec::new(),
            tape: Vec::new(),
            acts: SymBuf::fresh(program.din),
            pending: None,
            partial: std::collections::BTreeMap::new(),
            slot_of_buf: std::collections::BTreeMap::new(),
            cur: None,
            wave: None,
            pe_scales: vec![(1.0, 1.0); cfg.n_pes],
        }
        .run()
    }
}

// ---------------------------------------------------------------------------
// process-wide plan cache
// ---------------------------------------------------------------------------

/// Cache key: program content fingerprint + the machine parameters that
/// shape a plan (PE count and SRAM bound gate wave legality and
/// residency/streaming; the clock scales nothing in the tape today but is
/// part of the machine identity). The `Tech` model is deliberately *not*
/// part of the key: every [`super::Apu`] is constructed with
/// `Tech::tsmc16()` and has no setter, so plans never diverge on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct PlanKey {
    pub fingerprint: u64,
    pub n_pes: usize,
    pub pe_sram_bits: usize,
    pub clock_bits: u64,
}

impl PlanKey {
    pub(crate) fn new(fingerprint: u64, cfg: &ApuConfig) -> PlanKey {
        PlanKey {
            fingerprint,
            n_pes: cfg.n_pes,
            pe_sram_bits: cfg.pe_sram_bits,
            clock_bits: cfg.clock_ghz.to_bits(),
        }
    }
}

/// One cache entry: the shared plan (`None` = the planner bailed for
/// this program/machine — the failure is cached too, so N interpreter
/// fallbacks pay one failed build, not N) plus how many times a build
/// ran for this key (1 after first touch; tests assert it stays 1).
struct CacheSlot {
    plan: Option<Arc<ExecPlan>>,
    builds: u64,
}

static PLAN_CACHE: OnceLock<Mutex<HashMap<PlanKey, CacheSlot>>> = OnceLock::new();
static CACHE_BUILDS: AtomicU64 = AtomicU64::new(0);
static CACHE_HITS: AtomicU64 = AtomicU64::new(0);

fn cache() -> &'static Mutex<HashMap<PlanKey, CacheSlot>> {
    PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Process-wide plan cache counters (builds = plan compilations that
/// actually ran, hits = loads served from the cache).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanCacheStats {
    pub builds: u64,
    pub hits: u64,
    pub entries: usize,
}

pub fn plan_cache_stats() -> PlanCacheStats {
    PlanCacheStats {
        builds: CACHE_BUILDS.load(Ordering::Relaxed),
        hits: CACHE_HITS.load(Ordering::Relaxed),
        entries: cache().lock().unwrap().len(),
    }
}

/// Snapshot the process-wide plan-cache counters into `reg`, so the
/// cache shows up in metrics exports (`apu fleet --metrics-out`) next to
/// the shard counters instead of only in the CLI print. Gauges, not
/// counters: the registry's counter handles are additive, while these
/// are absolute process-wide figures — repeated exports must overwrite,
/// not re-add.
pub fn export_plan_cache_metrics(reg: &crate::obs::metrics::Registry) {
    let s = plan_cache_stats();
    reg.gauge(
        "apu_sim_plan_cache_builds",
        "plan compilations that actually ran (process-wide)",
        &[],
    )
    .set(s.builds as f64);
    reg.gauge(
        "apu_sim_plan_cache_hits",
        "program loads served from the plan cache (process-wide)",
        &[],
    )
    .set(s.hits as f64);
    reg.gauge(
        "apu_sim_plan_cache_entries",
        "distinct (program fingerprint, machine) plans cached (process-wide)",
        &[],
    )
    .set(s.entries as f64);
}

/// How many plan builds ran for (`fingerprint`, machine) — 0 if this key
/// was never loaded, 1 forever after (the per-key invariant N shards
/// rely on). Keyed lookups stay meaningful even when unrelated tests or
/// models churn the global counters concurrently.
pub fn plan_cache_builds(fingerprint: u64, cfg: &ApuConfig) -> u64 {
    cache().lock().unwrap().get(&PlanKey::new(fingerprint, cfg)).map_or(0, |s| s.builds)
}

/// Look up (or build-and-insert) the shared plan for `program` on `cfg`.
/// The map lock is held across a miss's build, so concurrent loaders of
/// the same model serialize into exactly one build — the others wait and
/// take the cached `Arc`. Returns `None` when the planner bails (the
/// caller falls back to the reference interpreter, as ever).
pub(crate) fn cached_plan(
    program: &Program,
    cfg: &ApuConfig,
    tech: &Tech,
    streamed: bool,
) -> Option<Arc<ExecPlan>> {
    let key = PlanKey::new(program.fingerprint(), cfg);
    let mut map = cache().lock().unwrap();
    if let Some(slot) = map.get(&key) {
        CACHE_HITS.fetch_add(1, Ordering::Relaxed);
        return slot.plan.clone();
    }
    CACHE_BUILDS.fetch_add(1, Ordering::Relaxed);
    let plan = ExecPlan::build(program, cfg, tech, streamed, key).ok().map(Arc::new);
    map.insert(key, CacheSlot { plan: plan.clone(), builds: 1 });
    plan
}

/// Resolve the shared execution plan for `program` on machine `cfg`
/// through the process-wide cache — the entry point model catalogs use
/// to pay one plan build for a whole fleet of shards. Validates the
/// program and computes weight residency exactly like [`super::Apu::load`];
/// `Ok(None)` means the planner declined and the program will run on the
/// reference interpreter.
pub fn shared_plan(program: &Program, cfg: &ApuConfig) -> Result<Option<Arc<ExecPlan>>> {
    program.validate()?;
    let (_, streamed) = weight_residency(program, cfg)?;
    Ok(cached_plan(program, cfg, &Tech::tsmc16(), streamed))
}

// ---------------------------------------------------------------------------
// builder (symbolic interpreter)
// ---------------------------------------------------------------------------

/// Symbolic per-layer context — mirrors the interpreter's `LayerCtx`.
struct Ctx {
    layer: u16,
    nb: usize,
    bh: usize,
    bw: usize,
    bits: u32,
    relu: bool,
    scales_loaded: usize,
}

/// Wave under construction.
struct WaveBuild {
    /// Decoded codes/bias per PE `g < nb` until `Compute` consumes them.
    codes: Vec<Option<Vec<i8>>>,
    bias: Vec<Option<Vec<f32>>>,
    moves: Vec<RouteMove>,
    scatters: Vec<ScatterExec>,
    /// Latch coverage of the most recent route (`nb * bw`).
    filled: Vec<bool>,
    /// Set at `Compute`: the finalized per-PE images.
    exec_pes: Option<Vec<WavePe>>,
}

/// Symbolic buffer: length + per-element owner PE tag.
struct SymBuf {
    len: usize,
    owner: Vec<u16>,
}

impl SymBuf {
    fn fresh(len: usize) -> SymBuf {
        SymBuf { len, owner: vec![u16::MAX; len] }
    }
}

struct Builder<'a> {
    key: PlanKey,
    program: &'a Program,
    cfg: &'a ApuConfig,
    tech: &'a Tech,
    streamed: bool,
    steps: Vec<ExecStep>,
    tape: Vec<TapeEntry>,
    acts: SymBuf,
    pending: Option<SymBuf>,
    /// Live partial buffers: buf id → (symbolic buffer, scratch slot).
    partial: std::collections::BTreeMap<u16, (SymBuf, usize)>,
    /// Stable buf-id → scratch-slot assignment (slots survive folds so a
    /// re-created buffer reuses its storage).
    slot_of_buf: std::collections::BTreeMap<u16, usize>,
    cur: Option<Ctx>,
    wave: Option<WaveBuild>,
    /// Persistent per-PE (w_scale, out_scale), as `SetScales` left them.
    pe_scales: Vec<(f32, f32)>,
}

impl Builder<'_> {
    fn run(mut self) -> Result<ExecPlan> {
        for insn in &self.program.insns {
            match insn {
                Insn::ConfigLayer { layer, nb, bh, bw, bits, relu } => {
                    self.finish_wave()?;
                    if self.cur.as_ref().map(|c| c.layer) != Some(*layer) {
                        self.commit();
                    }
                    let (nb, bh, bw) = (*nb as usize, *bh as usize, *bw as usize);
                    if nb > self.cfg.n_pes {
                        bail!("plan: wave has {nb} blocks but machine has {} PEs", self.cfg.n_pes);
                    }
                    // qmax/Quantizer panic below 2 bits: leave those
                    // panics on the interpreter path, don't plan them.
                    if *bits < 2 {
                        bail!("plan: sub-2-bit layer");
                    }
                    // PeUnit::configure's SRAM capacity check
                    let need = bh.checked_mul(bw).and_then(|x| x.checked_mul(*bits as usize));
                    match need {
                        Some(n) if n <= self.cfg.pe_sram_bits => {}
                        _ => bail!("plan: block exceeds PE SRAM"),
                    }
                    self.cur = Some(Ctx {
                        layer: *layer,
                        nb,
                        bh,
                        bw,
                        bits: *bits as u32,
                        relu: *relu,
                        scales_loaded: 0,
                    });
                    self.wave = Some(WaveBuild {
                        codes: vec![None; nb],
                        bias: vec![None; nb],
                        moves: Vec::new(),
                        scatters: Vec::new(),
                        filled: vec![false; nb * bw],
                        exec_pes: None,
                    });
                }
                Insn::LoadWeights { pe, seg } => self.load_weights(*pe, *seg)?,
                Insn::LoadBias { pe, seg } => self.load_bias(*pe, *seg)?,
                Insn::SetScales { pe, seg } => self.set_scales(*pe, *seg)?,
                Insn::Route { seg, from_input } => self.route(*seg, *from_input)?,
                Insn::Compute { rows } => self.compute(*rows as usize)?,
                Insn::Scatter { seg, buf } => self.scatter(*seg, *buf)?,
                Insn::HostOp { op, seg } => {
                    self.finish_wave()?;
                    self.commit();
                    self.host_op(*op, *seg)?;
                }
                Insn::HostDense { w_seg, b_seg, relu } => {
                    self.finish_wave()?;
                    self.commit();
                    self.host_dense(*w_seg, *b_seg, *relu)?;
                }
                Insn::Halt => break,
            }
        }
        self.finish_wave()?;
        self.commit();
        if !self.partial.is_empty() {
            bail!("plan: program ends with unfolded partial buffers");
        }
        if self.acts.len != self.program.dout {
            bail!("plan: program produces {} outputs, expected {}", self.acts.len, self.program.dout);
        }
        Ok(ExecPlan {
            steps: self.steps,
            tape: self.tape,
            n_partial_slots: self.slot_of_buf.len(),
            key: self.key,
        })
    }

    /// Append a charge, eliding all-zero charges like `Apu::charge`.
    fn push_tape(
        &mut self,
        layer: Option<u16>,
        phase: Phase,
        detail: &'static str,
        cycles: u64,
        pj: f64,
        macs: u64,
    ) {
        if cycles == 0 && pj == 0.0 && macs == 0 {
            return;
        }
        self.tape.push(TapeEntry { layer, phase, detail, cycles, pj, macs });
    }

    fn charge_host(&mut self, detail: &'static str, ops: usize) {
        let layer = self.cur.as_ref().map(|c| c.layer);
        self.push_tape(layer, Phase::Host, detail, ops as u64, ops as f64 * self.tech.host_pj_per_op, 0);
    }

    /// Symbolic `commit_pending`: emits a `Commit` step only when the
    /// pending buffer is non-empty (the interpreter's call is a no-op
    /// otherwise — including for a zero-length pending buffer).
    fn commit(&mut self) {
        if self.pending.as_ref().is_some_and(|p| p.len != 0) {
            self.acts = self.pending.take().unwrap();
            self.steps.push(ExecStep::Commit);
        }
    }

    /// Close the wave in flight: a computed-and-scattered wave becomes an
    /// `ExecStep::Wave`; a wave with no compute is a value no-op and is
    /// dropped (its route charges, if any, are already on the tape).
    fn finish_wave(&mut self) -> Result<()> {
        let Some(w) = self.wave.take() else { return Ok(()) };
        match w.exec_pes {
            None => Ok(()),
            Some(_) if w.scatters.is_empty() => {
                // Computed but never published: the interpreter would
                // still bump PE row counters — fall back rather than
                // diverge on the utilization metric.
                bail!("plan: computed wave without scatter")
            }
            Some(pes) => {
                let ctx = self.cur.as_ref().context("plan: wave without layer ctx")?;
                self.steps.push(ExecStep::Wave(Box::new(WaveExec {
                    nb: ctx.nb,
                    bh: ctx.bh,
                    bw: ctx.bw,
                    relu: ctx.relu,
                    pes,
                    moves: w.moves,
                    scatters: w.scatters,
                })));
                Ok(())
            }
        }
    }

    fn load_weights(&mut self, pe: u16, seg: u16) -> Result<()> {
        let codes = self.program.segment(seg)?.as_i8()?;
        let ctx = self.cur.as_ref().context("plan: LoadWeights before ConfigLayer")?;
        let (nb, bh, bw, bits, layer) = (ctx.nb, ctx.bh, ctx.bw, ctx.bits, ctx.layer);
        if self.streamed {
            let sbits = codes.len() * bits as usize;
            let pj = self.tech.dram_pj(sbits) + self.tech.sram_write_pj(sbits, self.cfg.pe_sram_bits);
            self.push_tape(Some(layer), Phase::Stream, "weight-stream", (sbits as u64).div_ceil(64), pj, 0);
        }
        if pe as usize >= nb {
            bail!("plan: LoadWeights to unconfigured PE {pe}");
        }
        if codes.len() != bh * bw {
            bail!("plan: weight segment {} != {bh}x{bw}", codes.len());
        }
        let q = Quantizer::qmax(bits);
        if codes.iter().any(|&c| (c as i32).abs() > q) {
            bail!("plan: weight code exceeds INT{bits} range");
        }
        let wave = self.wave.as_mut().context("plan: LoadWeights outside a wave")?;
        if wave.exec_pes.is_some() {
            bail!("plan: LoadWeights after Compute in one wave");
        }
        wave.codes[pe as usize] = Some(codes.to_vec());
        Ok(())
    }

    fn load_bias(&mut self, pe: u16, seg: u16) -> Result<()> {
        let b = self.program.segment(seg)?.as_f32()?;
        let ctx = self.cur.as_ref().context("plan: LoadBias before ConfigLayer")?;
        if pe as usize >= ctx.nb {
            bail!("plan: LoadBias to unconfigured PE {pe}");
        }
        if b.len() != ctx.bh {
            bail!("plan: bias segment {} != bh {}", b.len(), ctx.bh);
        }
        let wave = self.wave.as_mut().context("plan: LoadBias outside a wave")?;
        if wave.exec_pes.is_some() {
            bail!("plan: LoadBias after Compute in one wave");
        }
        wave.bias[pe as usize] = Some(b.to_vec());
        Ok(())
    }

    fn set_scales(&mut self, pe: u16, seg: u16) -> Result<()> {
        let s = self.program.segment(seg)?.as_f32()?;
        if s.len() != 2 {
            bail!("plan: scales segment must be [w_scale, out_scale]");
        }
        // Exactly PeUnit::set_scales' rejection condition; anything it
        // accepts (including NaN scales) flows through value-identically.
        if s[0] <= 0.0 || s[1] < 0.0 {
            bail!("plan: bad scales");
        }
        let slot = self.pe_scales.get_mut(pe as usize).context("plan: SetScales PE out of range")?;
        *slot = (s[0], s[1]);
        if let Some(c) = self.cur.as_mut() {
            c.scales_loaded += 1;
        }
        Ok(())
    }

    /// Symbolic routing phase: replicates the interpreter's cycle loop —
    /// same grouping by the schedule's `cycle` field, same per-group f64
    /// energy accumulation, same crossbar conflict and latch checks.
    fn route(&mut self, seg: u16, from_input: bool) -> Result<()> {
        let routes = self.program.segment(seg)?.as_routes()?;
        let n_pes = self.cfg.n_pes;
        let ctx = self.cur.as_ref().context("plan: Route before ConfigLayer")?;
        let (nb, bh, bw, layer) = (ctx.nb, ctx.bh, ctx.bw, ctx.layer);
        let bits = ctx.bits as usize;
        if ctx.scales_loaded < nb {
            bail!("plan: Route before all PE scales loaded");
        }
        let src_read = if from_input {
            self.tech.dram_pj(bits)
        } else {
            self.tech.sram_read_pj(bits, (bh * bits).max(1))
        };
        let pj_per_route = src_read
            + self.tech.mux_pj_per_bit * bits as f64
            + bits as f64 * self.tech.latch_pj_per_bit;
        let wave = self.wave.as_mut().context("plan: Route outside a wave")?;
        if wave.exec_pes.is_some() {
            bail!("plan: Route after Compute in one wave");
        }
        wave.filled.fill(false); // clear_latch
        let mut n_cycles = 0u32;
        let mut phase_pj = 0.0f64;
        let mut i = 0usize;
        let mut driven: Vec<Option<u32>> = vec![None; n_pes];
        let mut selected: Vec<Option<(usize, u32)>> = vec![None; n_pes];
        while i < routes.len() {
            let cycle = routes[i].cycle;
            driven.fill(None);
            selected.fill(None);
            let mut j = i;
            while j < routes.len() && routes[j].cycle == cycle {
                let a = routes[j];
                let act = a.act as usize;
                if act >= self.acts.len {
                    bail!("plan: route references activation {act} beyond buffer");
                }
                if !from_input {
                    let owner = self.acts.owner[act];
                    if owner != u16::MAX && owner != a.src % n_pes as u16 {
                        bail!("plan: route ownership conflict on act {act}");
                    }
                }
                let wire = a.src as usize % n_pes;
                if driven[wire].is_some() {
                    bail!("plan: wire {wire} driven twice in one cycle");
                }
                driven[wire] = Some(a.act);
                let dst = a.dst as usize;
                if dst >= n_pes {
                    bail!("plan: route dst {dst} out of range");
                }
                if selected[dst].is_some() {
                    bail!("plan: PE {dst} selects twice in one cycle");
                }
                selected[dst] = Some((wire, a.dst_slot));
                j += 1;
            }
            phase_pj += pj_per_route * (j - i) as f64;
            for (dst, sel) in selected.iter().enumerate() {
                let Some((wire, slot)) = *sel else { continue };
                if dst >= nb {
                    bail!("plan: route targets unconfigured PE {dst}");
                }
                let slot = slot as usize;
                if slot >= bw {
                    bail!("plan: latch slot {slot} out of range {bw}");
                }
                let f = &mut wave.filled[dst * bw + slot];
                if *f {
                    bail!("plan: latch slot written twice this wave");
                }
                *f = true;
                let act = driven[wire].context("plan: selected idle wire")?;
                wave.moves.push(RouteMove { act, dst: (dst * bw + slot) as u32 });
            }
            n_cycles += 1;
            i = j;
        }
        self.push_tape(Some(layer), Phase::Route, "route", n_cycles as u64, phase_pj, 0);
        Ok(())
    }

    fn compute(&mut self, rows: usize) -> Result<()> {
        let ctx = self.cur.as_ref().context("plan: Compute before ConfigLayer")?;
        let (nb, bh, bw, bits, layer) = (ctx.nb, ctx.bh, ctx.bw, ctx.bits, ctx.layer);
        if rows != bh {
            bail!("plan: Compute rows {rows} != configured bh {bh}");
        }
        let wave = self.wave.as_mut().context("plan: Compute outside a wave")?;
        if wave.exec_pes.is_some() {
            bail!("plan: repeated Compute in one wave");
        }
        if !wave.filled.iter().all(|&f| f) {
            bail!("plan: Compute with unfilled latch slots");
        }
        let mut pes = Vec::with_capacity(nb);
        for g in 0..nb {
            let codes = wave.codes[g].take().context("plan: Compute before weights loaded")?;
            let bias = wave.bias[g].take().unwrap_or_default();
            let (w_scale, out_scale) = self.pe_scales[g];
            let quant = if out_scale > 0.0 { Some(Quantizer::new(bits, out_scale)) } else { None };
            pes.push(WavePe { codes, bias, w_scale, quant });
        }
        wave.exec_pes = Some(pes);
        let pe_cfg = PeConfig { block_h: bh, block_w: bw, bits };
        let per_cycle = pe_energy_per_cycle(self.tech, &pe_cfg, PeMode::Spatial).total();
        self.push_tape(
            Some(layer),
            Phase::Compute,
            "compute",
            rows as u64,
            per_cycle * rows as f64 * nb as f64,
            (nb * bh * bw) as u64,
        );
        Ok(())
    }

    fn scatter(&mut self, seg: u16, buf: u16) -> Result<()> {
        let seg = self.program.segment(seg)?.as_u32()?;
        let ctx = self.cur.as_ref().context("plan: Scatter before ConfigLayer")?;
        let (nb, bh) = (ctx.nb, ctx.bh);
        let (dout, perm) = seg.split_first().context("plan: empty scatter segment")?;
        let dout = *dout as usize;
        if perm.len() != nb * bh {
            bail!("plan: scatter perm len {} != {nb}x{bh}", perm.len());
        }
        // Resolve the symbolic target (+ zero-init on first scatter).
        // The interpreter's pending-init test is `pending.is_empty()`,
        // so a zero-length pending buffer re-initializes too.
        let (target, init) = if buf == 0 {
            let init = !self.pending.as_ref().is_some_and(|p| p.len != 0);
            if init {
                self.pending = Some(SymBuf::fresh(dout));
            }
            (ScatterTarget::Pending, init)
        } else {
            let next = self.slot_of_buf.len();
            let slot = *self.slot_of_buf.entry(buf).or_insert(next);
            let init = !self.partial.contains_key(&buf);
            if init {
                self.partial.insert(buf, (SymBuf::fresh(dout), slot));
            }
            (ScatterTarget::Partial(slot), init)
        };
        let sym = match target {
            ScatterTarget::Pending => self.pending.as_mut().unwrap(),
            ScatterTarget::Partial(_) => &mut self.partial.get_mut(&buf).unwrap().0,
        };
        if sym.len != dout {
            bail!("plan: wave scatter dout {dout} != target buffer {} (buf {buf})", sym.len);
        }
        for g in 0..nb {
            for i in 0..bh {
                let global = perm[g * bh + i] as usize;
                if global >= dout {
                    bail!("plan: scatter index {global} out of range {dout}");
                }
                if sym.owner[global] != u16::MAX {
                    bail!("plan: scatter writes activation {global} twice (buffer {buf})");
                }
                sym.owner[global] = g as u16;
            }
        }
        let wave = self.wave.as_mut().context("plan: Scatter outside a wave")?;
        if wave.exec_pes.is_none() {
            bail!("plan: Scatter before Compute");
        }
        wave.scatters.push(ScatterExec { target, init, dout, perm: perm.to_vec() });
        Ok(())
    }

    fn host_op(&mut self, op: HostOpKind, seg: u16) -> Result<()> {
        let params = self.program.segment(seg)?.as_f32()?;
        let len = self.acts.len;
        match op {
            HostOpKind::Relu => {
                // owners unchanged: values stay where they were
                self.steps.push(ExecStep::Host(HostStep::Relu));
                self.charge_host("relu", len);
            }
            HostOpKind::Quantize => {
                let scale = *params.first().context("plan: Quantize needs [scale]")?;
                let bits = params.get(1).map(|&b| b as u32).unwrap_or(4);
                // Quantizer::new would panic on these — keep that panic
                // on the interpreter path instead of planning it.
                if scale <= 0.0 || scale.is_nan() || bits < 2 {
                    bail!("plan: invalid Quantize params");
                }
                self.steps.push(ExecStep::Host(HostStep::Quantize(Quantizer::new(bits, scale))));
                self.acts.owner.fill(u16::MAX);
                self.charge_host("quantize", len);
            }
            HostOpKind::MaxPool => {
                let [h, w, c, win, stride] = params else {
                    bail!("plan: MaxPool needs [h, w, c, window, stride]");
                };
                let (h, w, c, win, stride) =
                    (*h as usize, *w as usize, *c as usize, *win as usize, *stride as usize);
                let plane = h.checked_mul(w).and_then(|x| x.checked_mul(c));
                if plane != Some(len) || win == 0 || stride == 0 || win > h || win > w {
                    bail!("plan: invalid MaxPool geometry");
                }
                let out_len = ((h - win) / stride + 1) * ((w - win) / stride + 1) * c;
                self.steps.push(ExecStep::Host(HostStep::MaxPool { h, w, c, win, stride }));
                self.charge_host("maxpool", out_len * (2 * win * win - 1));
                self.acts = SymBuf::fresh(out_len);
            }
            HostOpKind::FoldAdd => {
                let &[src] = params else {
                    bail!("plan: FoldAdd params must be [src_buf]");
                };
                if !src.is_finite() || src.fract() != 0.0 || src < 1.0 || src > u16::MAX as f32 {
                    bail!("plan: invalid FoldAdd buffer id {src}");
                }
                let (sym, slot) = self
                    .partial
                    .remove(&(src as u16))
                    .context("plan: FoldAdd of missing partial buffer")?;
                if sym.len != len {
                    bail!("plan: FoldAdd buffer len {} != activation stream {len}", sym.len);
                }
                if sym.owner.iter().any(|&o| o == u16::MAX) {
                    bail!("plan: FoldAdd of incomplete partial buffer");
                }
                self.steps.push(ExecStep::Host(HostStep::FoldAdd(slot)));
                self.charge_host("fold-add", len);
                self.acts.owner.fill(u16::MAX);
            }
            HostOpKind::Gather => {
                let mut idx = Vec::with_capacity(params.len());
                for &v in params {
                    if !v.is_finite() || v.fract() != 0.0 {
                        bail!("plan: Gather index {v} is not finite/integral");
                    }
                    if v < 0.0 {
                        idx.push(-1i64);
                        continue;
                    }
                    let i = v as usize;
                    if i >= len {
                        bail!("plan: Gather index {i} out of range");
                    }
                    idx.push(i as i64);
                }
                let out_len = idx.len();
                self.steps.push(ExecStep::Host(HostStep::Gather(idx)));
                self.charge_host("gather", out_len);
                self.acts = SymBuf::fresh(out_len);
            }
        }
        Ok(())
    }

    fn host_dense(&mut self, w_seg: u16, b_seg: u16, relu: bool) -> Result<()> {
        let w = self.program.segment(w_seg)?.as_f32()?;
        let b = self.program.segment(b_seg)?.as_f32()?;
        let din = self.acts.len;
        let dout = b.len();
        if w.len() != dout * din {
            bail!("plan: host dense weight len {} != {dout}x{din}", w.len());
        }
        self.steps.push(ExecStep::Host(HostStep::Dense { w: w.to_vec(), b: b.to_vec(), din, relu }));
        let ops = dout * din;
        let layer = self.cur.as_ref().map(|c| c.layer);
        self.push_tape(layer, Phase::Host, "dense", ops as u64, ops as f64 * self.tech.host_pj_per_op, ops as u64);
        self.acts = SymBuf::fresh(dout);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// executor
// ---------------------------------------------------------------------------

/// Walk every plan step over a slice of batch lanes — the per-worker
/// loop of `Apu::run_batch`. Lanes are fully independent, so a worker
/// needs only its own lanes, a private scratch, and a private per-PE row
/// counter (summed into the lifetime counter by the caller); value
/// semantics are identical for any partition of the batch.
/// `lane_major` forces the legacy lane-at-a-time wave kernel instead of
/// the batch-major one (bitwise identical — kept so the bench harness
/// can compare the two traversals).
pub(crate) fn execute_steps(
    steps: &[ExecStep],
    lanes: &mut [StreamState],
    scratch: &mut WaveScratch,
    rows: &mut [u64],
    lane_major: bool,
) {
    for step in steps {
        match step {
            ExecStep::Commit => {
                for st in lanes.iter_mut() {
                    std::mem::swap(&mut st.acts, &mut st.pending);
                    st.pending.clear();
                }
            }
            ExecStep::Wave(w) => {
                if lane_major {
                    for st in lanes.iter_mut() {
                        w.apply(st, scratch, rows);
                    }
                } else {
                    w.apply_lanes(lanes, scratch, rows);
                }
            }
            ExecStep::Host(h) => {
                for st in lanes.iter_mut() {
                    h.apply(st);
                }
            }
        }
    }
}

impl WaveExec {
    /// Execute this wave for one stream: latch moves, the MAC phase into
    /// flat scratch (bitwise the PE datapath: f64 left-to-right dot, f32
    /// scale + bias, ReLU, grid snap), then the scatters. `rows` is the
    /// per-PE row counter.
    pub(crate) fn apply(&self, st: &mut StreamState, scratch: &mut WaveScratch, rows: &mut [u64]) {
        let (nb, bh, bw) = (self.nb, self.bh, self.bw);
        if scratch.latch.len() < nb * bw {
            scratch.latch.resize(nb * bw, 0.0);
        }
        if scratch.out.len() < nb * bh {
            scratch.out.resize(nb * bh, 0.0);
        }
        // Every slot a PE reads was validated as latch-covered at plan
        // time, so stale scratch lanes are never observed.
        for m in &self.moves {
            scratch.latch[m.dst as usize] = st.acts[m.act as usize];
        }
        for (g, pe) in self.pes.iter().enumerate() {
            let latch = &scratch.latch[g * bw..(g + 1) * bw];
            let out = &mut scratch.out[g * bh..(g + 1) * bh];
            mac_rows(pe, latch, out, bh, bw, self.relu);
            if let Some(q) = &pe.quant {
                q.fake_slice(out);
            }
            rows[g] += bh as u64;
        }
        for s in &self.scatters {
            let buf = match s.target {
                ScatterTarget::Pending => &mut st.pending,
                ScatterTarget::Partial(slot) => &mut st.partial[slot],
            };
            if s.init {
                buf.clear();
                buf.resize(s.dout, 0.0);
            }
            for (k, &global) in s.perm.iter().enumerate() {
                buf[global as usize] = scratch.out[k];
            }
        }
    }

    /// Execute this wave for every lane in `lanes`, weight-stationary:
    /// each PE's weight rows are walked once, applying every row across
    /// all lanes before moving to the next (batch-major traversal —
    /// exactly the weight reuse the paper's PE scheduling targets),
    /// instead of re-walking the whole block per lane. Per-lane math —
    /// the f64 left-to-right dot, f32 scale + bias, ReLU, grid snap,
    /// scatter order — is exactly [`WaveExec::apply`]'s, so every lane's
    /// outputs are bitwise identical to a lane-at-a-time walk; only the
    /// traversal order (and therefore weight-row locality) changes.
    pub(crate) fn apply_lanes(
        &self,
        lanes: &mut [StreamState],
        scratch: &mut WaveScratch,
        rows: &mut [u64],
    ) {
        if lanes.len() == 1 {
            // Single lane: the blocked-row kernel has better latch reuse.
            self.apply(&mut lanes[0], scratch, rows);
            return;
        }
        let (nb, bh, bw) = (self.nb, self.bh, self.bw);
        let n = lanes.len();
        let lane_latch = nb * bw;
        let lane_out = nb * bh;
        if scratch.latch.len() < n * lane_latch {
            scratch.latch.resize(n * lane_latch, 0.0);
        }
        if scratch.out.len() < n * lane_out {
            scratch.out.resize(n * lane_out, 0.0);
        }
        for (k, st) in lanes.iter().enumerate() {
            let latch = &mut scratch.latch[k * lane_latch..(k + 1) * lane_latch];
            for m in &self.moves {
                latch[m.dst as usize] = st.acts[m.act as usize];
            }
        }
        for (g, pe) in self.pes.iter().enumerate() {
            for row in 0..bh {
                let base = row * bw;
                let codes = &pe.codes[base..base + bw];
                let bias = pe.bias.get(row).copied().unwrap_or(0.0);
                for k in 0..n {
                    let off = k * lane_latch + g * bw;
                    let latch = &scratch.latch[off..off + bw];
                    let acc: f64 =
                        codes.iter().zip(latch).map(|(&c, &a)| c as f64 * a as f64).sum();
                    let mut v = acc as f32 * pe.w_scale + bias;
                    if self.relu {
                        v = v.max(0.0);
                    }
                    scratch.out[k * lane_out + g * bh + row] = v;
                }
            }
            if let Some(q) = &pe.quant {
                for k in 0..n {
                    let off = k * lane_out + g * bh;
                    q.fake_slice(&mut scratch.out[off..off + bh]);
                }
            }
            rows[g] += (n * bh) as u64;
        }
        for (k, st) in lanes.iter_mut().enumerate() {
            let out = &scratch.out[k * lane_out..(k + 1) * lane_out];
            for s in &self.scatters {
                let buf = match s.target {
                    ScatterTarget::Pending => &mut st.pending,
                    ScatterTarget::Partial(slot) => &mut st.partial[slot],
                };
                if s.init {
                    buf.clear();
                    buf.resize(s.dout, 0.0);
                }
                for (i, &global) in s.perm.iter().enumerate() {
                    buf[global as usize] = out[i];
                }
            }
        }
    }
}

/// One PE's MAC phase over `bh` rows: per-row strictly left-to-right f64
/// dot (bitwise the PE datapath), f32 scale + bias, optional ReLU. Rows
/// are blocked four at a time so each latch element is loaded once per
/// block and feeds four independent accumulators; within a row the
/// summation order is untouched, so every output bit is unchanged.
fn mac_rows(pe: &WavePe, latch: &[f32], out: &mut [f32], bh: usize, bw: usize, relu: bool) {
    let finish = |acc: f64, row: usize| {
        let v = acc as f32 * pe.w_scale + pe.bias.get(row).copied().unwrap_or(0.0);
        if relu {
            v.max(0.0)
        } else {
            v
        }
    };
    let mut row = 0;
    while row + 4 <= bh {
        let base = row * bw;
        let c0 = &pe.codes[base..base + bw];
        let c1 = &pe.codes[base + bw..base + 2 * bw];
        let c2 = &pe.codes[base + 2 * bw..base + 3 * bw];
        let c3 = &pe.codes[base + 3 * bw..base + 4 * bw];
        let (mut a0, mut a1, mut a2, mut a3) = (0f64, 0f64, 0f64, 0f64);
        for (k, &a) in latch.iter().enumerate() {
            let x = a as f64;
            a0 += c0[k] as f64 * x;
            a1 += c1[k] as f64 * x;
            a2 += c2[k] as f64 * x;
            a3 += c3[k] as f64 * x;
        }
        out[row] = finish(a0, row);
        out[row + 1] = finish(a1, row + 1);
        out[row + 2] = finish(a2, row + 2);
        out[row + 3] = finish(a3, row + 3);
        row += 4;
    }
    while row < bh {
        let base = row * bw;
        let acc: f64 =
            pe.codes[base..base + bw].iter().zip(latch).map(|(&c, &a)| c as f64 * a as f64).sum();
        out[row] = finish(acc, row);
        row += 1;
    }
}

impl HostStep {
    /// Execute this host op for one stream, value-identical to the
    /// interpreter's `host_op`/`host_dense`. Buffer swaps go through the
    /// stream's pending scratch so nothing is reallocated per run
    /// (`MaxPool` allocates its output, as the interpreter does).
    pub(crate) fn apply(&self, st: &mut StreamState) {
        match self {
            HostStep::Relu => {
                for v in &mut st.acts {
                    *v = v.max(0.0);
                }
            }
            HostStep::Quantize(q) => q.fake_slice(&mut st.acts),
            HostStep::MaxPool { h, w, c, win, stride } => {
                let out = host_maxpool(&st.acts, *h, *w, *c, *win, *stride)
                    .expect("plan validated maxpool geometry");
                st.acts = out;
            }
            HostStep::FoldAdd(slot) => {
                let StreamState { acts, partial, .. } = st;
                for (v, &p) in acts.iter_mut().zip(&partial[*slot]) {
                    *v += p;
                }
            }
            HostStep::Gather(idx) => {
                st.pending.clear();
                st.pending.reserve(idx.len());
                for &i in idx {
                    st.pending.push(if i < 0 { 0.0 } else { st.acts[i as usize] });
                }
                std::mem::swap(&mut st.acts, &mut st.pending);
                st.pending.clear();
            }
            HostStep::Dense { w, b, din, relu } => {
                st.pending.clear();
                st.pending.reserve(b.len());
                for (r, &bv) in b.iter().enumerate() {
                    let row = &w[r * din..(r + 1) * din];
                    let mut acc = 0f32;
                    for (x, wv) in st.acts.iter().zip(row) {
                        acc += x * wv;
                    }
                    st.pending.push(if *relu { (acc + bv).max(0.0) } else { acc + bv });
                }
                std::mem::swap(&mut st.acts, &mut st.pending);
                st.pending.clear();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Registry;

    #[test]
    fn export_snapshots_cache_counters_as_gauges() {
        let reg = Registry::new();
        export_plan_cache_metrics(&reg);
        let snap = plan_cache_stats();
        // Registration is idempotent: re-requesting the gauge returns the
        // handle the export wrote through. Other tests churn the global
        // cache concurrently, so assert against a fresh snapshot's lower
        // bound rather than exact equality.
        let builds = reg
            .gauge("apu_sim_plan_cache_builds", "plan compilations that actually ran (process-wide)", &[])
            .get();
        let entries = reg
            .gauge(
                "apu_sim_plan_cache_entries",
                "distinct (program fingerprint, machine) plans cached (process-wide)",
                &[],
            )
            .get();
        assert!(builds >= 0.0 && builds <= snap.builds as f64);
        assert!(entries >= 0.0 && entries <= snap.entries as f64);
        // Re-export overwrites (gauge semantics), never accumulates.
        export_plan_cache_metrics(&reg);
        let again = reg
            .gauge("apu_sim_plan_cache_builds", "plan compilations that actually ran (process-wide)", &[])
            .get();
        assert!(again <= plan_cache_stats().builds as f64);
    }

    #[test]
    fn blocked_mac_rows_matches_the_scalar_dot_bitwise() {
        // 7 rows exercises one full 4-row block plus a 3-row tail.
        let (bh, bw) = (7usize, 5usize);
        let codes: Vec<i8> = (0..bh * bw).map(|i| ((i * 37 + 11) % 15) as i8 - 7).collect();
        let bias: Vec<f32> = (0..bh).map(|i| i as f32 * 0.125 - 0.25).collect();
        let latch: Vec<f32> = (0..bw).map(|i| (i as f32 * 0.731).sin()).collect();
        let pe = WavePe { codes, bias, w_scale: 0.173, quant: None };
        for relu in [false, true] {
            let mut got = vec![0f32; bh];
            mac_rows(&pe, &latch, &mut got, bh, bw, relu);
            for row in 0..bh {
                let base = row * bw;
                let acc: f64 = pe.codes[base..base + bw]
                    .iter()
                    .zip(&latch)
                    .map(|(&c, &a)| c as f64 * a as f64)
                    .sum();
                let mut want = acc as f32 * pe.w_scale + pe.bias[row];
                if relu {
                    want = want.max(0.0);
                }
                assert_eq!(got[row].to_bits(), want.to_bits(), "row {row} relu {relu}");
            }
        }
    }
}
