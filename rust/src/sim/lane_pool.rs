//! Scoped worker pool for parallel batch-lane execution (std-only).
//!
//! Batch lanes in the planned datapath are fully independent
//! [`super::plan::StreamState`]s, so `Apu::run_batch` can partition them
//! into contiguous chunks and walk the plan once per chunk on its own
//! worker. The pool is deliberately minimal: [`run`] executes a vector
//! of closures under [`std::thread::scope`], running the *first* job on
//! the calling thread — a single-job call spawns no threads at all, so
//! `threads = 1` is exactly the historical sequential path, not a
//! simulation of it. Worker panics are re-raised on the caller after
//! every spawned job has been joined.
//!
//! Nothing here touches charge accounting: the charge-tape replay stays
//! on the calling thread in lane order (see `Apu::run_planned`), which
//! is what keeps `SimStats`/`SimProfile` bitwise identical for any
//! thread count.

use std::sync::OnceLock;

use crate::obs::metrics::{self, Counter, Gauge};

/// Split `n` lanes across at most `threads` workers: contiguous chunks
/// of `ceil(n / threads)` lanes. Returns `(chunk, workers)` where
/// `workers` is the number of non-empty chunks actually used — full
/// chunks are preferred over spreading thin (fewer, warmer workers).
pub(crate) fn partition(n: usize, threads: usize) -> (usize, usize) {
    let threads = threads.max(1);
    if n == 0 {
        return (1, 0);
    }
    let chunk = n.div_ceil(threads);
    (chunk, n.div_ceil(chunk))
}

/// Run every job to completion, the first on the calling thread and the
/// rest on scoped worker threads. A panicking worker is re-raised here
/// after all handles are joined (the scope also guarantees no job can
/// outlive its borrows).
pub(crate) fn run<F>(mut jobs: Vec<F>)
where
    F: FnOnce() + Send,
{
    if jobs.len() <= 1 {
        if let Some(job) = jobs.pop() {
            job();
        }
        return;
    }
    let rest = jobs.split_off(1);
    let first = jobs.pop().expect("one job left after split_off(1)");
    std::thread::scope(|s| {
        let handles: Vec<_> = rest.into_iter().map(|job| s.spawn(job)).collect();
        first();
        for h in handles {
            if let Err(payload) = h.join() {
                std::panic::resume_unwind(payload);
            }
        }
    });
}

/// Lane-pool utilization handles on the process-global metrics registry.
pub(crate) struct LaneInstruments {
    /// `apu_sim_lane_workers`: workers used by the most recent planned
    /// batch (a gauge — fleets read it as "current parallel width").
    pub(crate) workers: Gauge,
    /// `apu_sim_lane_steps_total`: plan-step executions summed over
    /// lanes (`lanes × steps` per batch) — the work the pool divided.
    pub(crate) steps: Counter,
}

/// Lazily register the lane metrics on [`metrics::global`] (idempotent;
/// one process-wide pair, shared by every `Apu`).
pub(crate) fn instruments() -> &'static LaneInstruments {
    static INS: OnceLock<LaneInstruments> = OnceLock::new();
    INS.get_or_init(|| {
        let reg = metrics::global();
        LaneInstruments {
            workers: reg.gauge(
                "apu_sim_lane_workers",
                "lane-pool workers used by the most recent planned batch",
                &[],
            ),
            steps: reg.counter(
                "apu_sim_lane_steps_total",
                "plan-step executions across batch lanes (lanes x steps)",
                &[],
            ),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn partition_prefers_full_chunks() {
        assert_eq!(partition(0, 4), (1, 0));
        assert_eq!(partition(1, 1), (1, 1));
        assert_eq!(partition(32, 1), (32, 1));
        assert_eq!(partition(32, 4), (8, 4));
        // 5 lanes on 4 workers: chunks of 2 → only 3 workers used
        assert_eq!(partition(5, 4), (2, 3));
        // more workers than lanes: one lane each
        assert_eq!(partition(3, 8), (1, 3));
        // threads = 0 is clamped to sequential
        assert_eq!(partition(7, 0), (7, 1));
    }

    #[test]
    fn run_executes_every_job_exactly_once() {
        for n_jobs in [0usize, 1, 2, 5] {
            let hits = AtomicUsize::new(0);
            let jobs: Vec<_> = (0..n_jobs)
                .map(|_| {
                    let hits = &hits;
                    move || {
                        hits.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .collect();
            run(jobs);
            assert_eq!(hits.load(Ordering::Relaxed), n_jobs);
        }
    }

    #[test]
    fn run_gives_each_job_exclusive_mutable_state() {
        let mut slots = vec![0u64; 6];
        let jobs: Vec<_> = slots
            .chunks_mut(2)
            .enumerate()
            .map(|(i, chunk)| {
                move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (10 * i + j) as u64;
                    }
                }
            })
            .collect();
        run(jobs);
        assert_eq!(slots, vec![0, 1, 10, 11, 20, 21]);
    }

    #[test]
    fn worker_panic_propagates_to_the_caller() {
        let caught = std::panic::catch_unwind(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("lane worker boom")),
            ];
            run(jobs);
        });
        assert!(caught.is_err());
    }
}
