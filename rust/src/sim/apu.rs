//! The APU machine: PE array + crossbar + host core executing programs.
//!
//! ## Folding (paper §4.4.3-II, Fig. 15's VGGFC6)
//!
//! A layer with more blocks than PEs is compiled into *waves*: several
//! `ConfigLayer` groups sharing one `layer` id. Wave scatters accumulate
//! into a pending buffer that commits to the visible activation stream
//! when the next layer id appears (or at program end). Layers whose total
//! weight footprint exceeds the PE SRAM residency are *streamed*: their
//! weight DMA is charged on every inference instead of once at load —
//! exactly the effect that makes the paper's VGGFC6 speedup dip.
//!
//! ## Named partial-sum buffers (§4.4.3-II column tiles)
//!
//! A layer whose block/kernel exceeds one PE is tiled; each *column*
//! tile produces partial sums for the same outputs. Wave scatters with
//! `buf >= 1` land in named host buffers (with per-element ownership
//! tracking, so a tile that writes an output twice or never is caught);
//! the layer's `FoldAdd` host ops then fold each buffer into the
//! committed stream at one add per element — runtime operands, not
//! compile-time constants. Bias rides column tile 0 and ReLU/output
//! quantization run as host ops after the last fold, so they apply
//! exactly once.
//!
//! ## Plan/execute split (what is amortized vs. charged per inference)
//!
//! `load` does the work that is identical for every inference exactly
//! once: program validation, residency analysis, and — via
//! [`super::plan::ExecPlan::build`] — segment decoding (routes, perms,
//! weight codes, bias, scales), crossbar conflict/latch/ownership
//! checking, per-layer PE configuration images, and the *charge tape*:
//! the exact cycle/energy/MAC sequence one inference books (possible
//! because every simulator charge depends only on program structure,
//! never on activation values). `run`/`run_batch` then execute the
//! pre-decoded steps over reusable scratch buffers (cleared, never
//! reallocated) and replay the tape per inference, producing
//! [`SimStats`]/[`SimProfile`] accumulations bitwise identical to the
//! reference interpreter ([`Apu::run_reference`]).
//!
//! Still charged per inference, exactly as before: route/compute/host
//! cycles and energy, and — for *streamed* programs whose weights
//! exceed PE SRAM residency — the per-run weight DMA (the VGGFC6
//! folding dip), which rides the tape's `weight-stream` entries. The
//! one-time resident weight DMA stays charged at `load` (`load_pj`).
//!
//! Programs whose shape the planner does not support (including any
//! program that would fail at run time) fall back to the interpreter
//! transparently: `load` keeps `exec = None` and `run` behaves — errors,
//! charges, and all — exactly as it always did.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::lane_pool;
use super::pe::PeUnit;
use super::plan::{ExecPlan, StreamState, WaveScratch};
use super::profile::{Phase, SimProfile};
use crate::hwmodel::{pe_energy_per_cycle, PeConfig, PeMode, Tech};
use crate::isa::{DataSegment, HostOpKind, Insn, Program};
use crate::pruning::Quantizer;
use crate::routing::MuxCrossbar;

/// Machine parameters (one generated design instance).
#[derive(Debug, Clone)]
pub struct ApuConfig {
    pub n_pes: usize,
    /// Weight SRAM capacity per PE, bits.
    pub pe_sram_bits: usize,
    pub clock_ghz: f64,
}

impl Default for ApuConfig {
    /// The paper's silicon instance: 10 PEs, 640 kb weight SRAM each
    /// (400×400 INT4), 1 GHz.
    fn default() -> Self {
        ApuConfig { n_pes: 10, pe_sram_bits: 640_000, clock_ghz: 1.0 }
    }
}

/// Execution knobs for the planned datapath. Every setting is
/// *bitwise-invisible*: outputs, [`SimStats`], and [`SimProfile`] do not
/// depend on it (the determinism matrix in `integration_plan` enforces
/// this), so callers tune purely for wall-clock speed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecOptions {
    /// Worker threads for planned batch execution: the batch's lanes are
    /// partitioned into contiguous chunks, one scoped worker per chunk
    /// (see [`super::lane_pool`]). `1` (the default) spawns no threads —
    /// it is exactly the historical sequential path.
    pub threads: usize,
    /// Use the legacy lane-at-a-time wave kernel instead of the
    /// batch-major weight-stationary one. Kept so the bench harness can
    /// compare the kernels; never faster, always bitwise identical.
    pub lane_major_kernel: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { threads: 1, lane_major_kernel: false }
    }
}

/// Cycle and energy accounting, accumulated across `run` calls.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimStats {
    pub route_cycles: u64,
    pub compute_cycles: u64,
    pub host_cycles: u64,
    pub route_pj: f64,
    pub compute_pj: f64,
    pub host_pj: f64,
    /// One-time weight/program DMA energy (charged at `load`).
    pub load_pj: f64,
    /// Per-run weight streaming DMA (folded layers that don't fit).
    pub stream_pj: f64,
    /// Cycles stalled on weight streaming (64-bit DMA bus).
    pub stream_cycles: u64,
    pub macs: u64,
    pub inferences: u64,
}

impl SimStats {
    pub fn total_cycles(&self) -> u64 {
        self.route_cycles + self.compute_cycles + self.host_cycles + self.stream_cycles
    }

    pub fn total_pj(&self) -> f64 {
        self.route_pj + self.compute_pj + self.host_pj + self.stream_pj
    }

    /// Wall-clock seconds at the configured clock. A zero/negative or
    /// non-finite clock yields 0.0 instead of ±inf/NaN (which would
    /// poison every derived TOPS/W figure downstream).
    pub fn seconds(&self, clock_ghz: f64) -> f64 {
        if clock_ghz <= 0.0 || !clock_ghz.is_finite() {
            return 0.0;
        }
        self.total_cycles() as f64 / (clock_ghz * 1e9)
    }

    /// Paper-normalized ops (§4.3): 4 ops per MAC slot (multiply + the
    /// mixed-precision tree + quantize, re-expressed at base precision).
    pub fn normalized_ops(&self) -> f64 {
        4.0 * self.macs as f64
    }

    /// Effective throughput in GOPS at the configured clock; 0.0 when
    /// nothing ran or the clock is invalid — never inf/NaN.
    pub fn effective_gops(&self, clock_ghz: f64) -> f64 {
        let s = self.seconds(clock_ghz);
        if s <= 0.0 {
            return 0.0;
        }
        self.normalized_ops() / s / 1e9
    }

    /// Energy efficiency, TOPS/W ≡ normalized ops per pJ; 0.0 when no
    /// energy was charged — never inf/NaN.
    pub fn tops_per_watt(&self) -> f64 {
        let pj = self.total_pj();
        if pj <= 0.0 || !pj.is_finite() {
            return 0.0;
        }
        self.normalized_ops() / pj
    }
}

#[derive(Debug, Clone)]
struct LoadedProgram {
    program: Arc<Program>,
    /// Total resident weight bits (one-time DMA).
    weight_bits: u64,
    /// True if weights exceed residency: stream per run.
    streamed: bool,
    /// Pre-decoded execution plan, shared process-wide via the
    /// [`super::plan`] cache; `None` falls back to the interpreter.
    exec: Option<Arc<ExecPlan>>,
}

/// Weight residency of a program on a machine: total resident weight
/// bits (the one-time DMA) and whether any PE's footprint exceeds its
/// SRAM (→ weights stream per run). Pure over (program, config) — the
/// plan cache derives the `streamed` flag from the same computation
/// `Apu::load` charges from, so they can never disagree.
pub(crate) fn weight_residency(program: &Program, cfg: &ApuConfig) -> Result<(u64, bool)> {
    let mut per_pe_bits = vec![0u64; cfg.n_pes];
    let mut weight_bits = 0u64;
    let mut cur_bits = 4u32;
    // Residency = the union of distinct segments each PE ever holds;
    // re-issuing LoadWeights for the same segment (the compiler does
    // this for ragged conv tail waves) adds no footprint.
    let mut seen = std::collections::HashSet::new();
    for insn in &program.insns {
        match insn {
            Insn::ConfigLayer { nb, bits, .. } => {
                if *nb as usize > cfg.n_pes {
                    bail!("wave has {nb} blocks but machine has {} PEs (compiler must fold)", cfg.n_pes);
                }
                cur_bits = *bits as u32;
            }
            Insn::LoadWeights { pe, seg } => {
                if *pe as usize >= cfg.n_pes {
                    bail!("LoadWeights pe {pe} out of range");
                }
                if seen.insert((*pe, *seg)) {
                    let n = program.segment(*seg)?.as_i8()?.len() as u64;
                    let bits = n * cur_bits as u64;
                    per_pe_bits[*pe as usize] += bits;
                    weight_bits += bits;
                }
            }
            _ => {}
        }
    }
    let streamed = per_pe_bits.iter().any(|&b| b > cfg.pe_sram_bits as u64);
    Ok((weight_bits, streamed))
}

/// Program handles [`Apu::load`] accepts: an owned or shared program is
/// taken without copying; `&Program` clones once (the historical
/// behavior, kept so existing call sites stay source-compatible).
pub trait IntoProgramArc {
    fn into_program_arc(self) -> Arc<Program>;
}

impl IntoProgramArc for Arc<Program> {
    fn into_program_arc(self) -> Arc<Program> {
        self
    }
}

impl IntoProgramArc for &Arc<Program> {
    fn into_program_arc(self) -> Arc<Program> {
        Arc::clone(self)
    }
}

impl IntoProgramArc for Program {
    fn into_program_arc(self) -> Arc<Program> {
        Arc::new(self)
    }
}

impl IntoProgramArc for &Program {
    fn into_program_arc(self) -> Arc<Program> {
        Arc::new(self.clone())
    }
}

/// The simulated machine.
#[derive(Debug)]
pub struct Apu {
    pub cfg: ApuConfig,
    tech: Tech,
    pes: Vec<PeUnit>,
    crossbar: MuxCrossbar,
    plan: Option<LoadedProgram>,
    stats: SimStats,
    /// Committed activations (the routing phase's source stream).
    acts: Vec<f32>,
    act_owner: Vec<u16>,
    /// Pending layer accumulation (wave scatters land here).
    pending: Vec<f32>,
    pending_owner: Vec<u16>,
    /// Named runtime partial-sum buffers (§4.4.3-II column tiles):
    /// scatters with `buf >= 1` land here until a `FoldAdd` host op
    /// folds them into the activation stream. Values + per-element
    /// owner PE (for exactly-once tracking).
    partial: std::collections::BTreeMap<u16, (Vec<f32>, Vec<u16>)>,
    cur: Option<LayerCtx>,
    /// Optional per-charge profile mirror (see [`SimProfile`]); `None`
    /// keeps the hot path allocation-free.
    profile: Option<SimProfile>,
    /// Per-element value state for the planned executor (one per batch
    /// lane, grown on demand, buffers reused across runs).
    streams: Vec<StreamState>,
    /// Per-worker latch/output scratch for planned waves (index = lane-
    /// pool worker slot; slot 0 is the calling thread).
    scratches: Vec<WaveScratch>,
    /// Per-worker planned row counters, zeroed per batch and summed into
    /// `planned_rows` after the workers join (u64 adds — the merge is
    /// order-free, so the total is thread-count independent).
    worker_rows: Vec<Vec<u64>>,
    /// Rows computed by the planned executor, per PE (the interpreter's
    /// counterpart lives in each [`PeUnit`]).
    planned_rows: Vec<u64>,
    /// Planned-datapath execution knobs (bitwise-invisible tuning).
    opts: ExecOptions,
}

#[derive(Debug, Clone)]
struct LayerCtx {
    layer_id: u16,
    nb: usize,
    bh: usize,
    bw: usize,
    bits: u32,
    scales_loaded: usize,
}

impl Apu {
    pub fn new(cfg: ApuConfig) -> Apu {
        let pes = (0..cfg.n_pes).map(|_| PeUnit::new(cfg.pe_sram_bits)).collect();
        let crossbar = MuxCrossbar::new(cfg.n_pes);
        let planned_rows = vec![0u64; cfg.n_pes];
        Apu {
            cfg,
            tech: Tech::tsmc16(),
            pes,
            crossbar,
            plan: None,
            stats: SimStats::default(),
            acts: Vec::new(),
            act_owner: Vec::new(),
            pending: Vec::new(),
            pending_owner: Vec::new(),
            partial: std::collections::BTreeMap::new(),
            cur: None,
            profile: None,
            streams: Vec::new(),
            scratches: Vec::new(),
            worker_rows: Vec::new(),
            planned_rows,
            opts: ExecOptions::default(),
        }
    }

    /// The planned-datapath execution knobs currently in effect.
    pub fn exec_options(&self) -> &ExecOptions {
        &self.opts
    }

    /// Set the planned-datapath execution knobs (threads, kernel). Takes
    /// effect on the next `run`/`run_batch`; bitwise-invisible in
    /// outputs, stats, and profile.
    pub fn set_exec_options(&mut self, opts: ExecOptions) {
        self.opts = opts;
    }

    /// Convenience: set just the lane-pool worker count (`0` is clamped
    /// to `1`, the sequential path).
    pub fn set_threads(&mut self, threads: usize) {
        self.opts.threads = threads.max(1);
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// Zero the accumulated stats; an enabled profile is cleared too so
    /// the two never disagree.
    pub fn reset_stats(&mut self) {
        self.stats = SimStats::default();
        if let Some(p) = self.profile.as_mut() {
            *p = SimProfile::default();
        }
    }

    /// Start mirroring every charge into a [`SimProfile`] (idempotent).
    pub fn enable_profiling(&mut self) {
        if self.profile.is_none() {
            self.profile = Some(SimProfile::default());
        }
    }

    pub fn profile(&self) -> Option<&SimProfile> {
        self.profile.as_ref()
    }

    /// Detach the recorded profile (disables further profiling).
    pub fn take_profile(&mut self) -> Option<SimProfile> {
        self.profile.take()
    }

    /// Lifetime rows computed per PE (utilization accounting). Sums the
    /// interpreter's per-PE counters with the planned executor's.
    pub fn pe_rows_computed(&self) -> Vec<u64> {
        self.pes
            .iter()
            .zip(&self.planned_rows)
            .map(|(pe, &planned)| pe.rows_computed() + planned)
            .collect()
    }

    /// Book `cycles`/`pj`/`macs` into `phase`, attributing to the current
    /// layer context (interpreter path).
    fn charge(&mut self, phase: Phase, detail: &'static str, cycles: u64, pj: f64, macs: u64) {
        let layer = self.cur.as_ref().map(|c| c.layer_id);
        self.charge_at(layer, phase, detail, cycles, pj, macs);
    }

    /// Book a charge against an explicit layer, mirroring the identical
    /// increments into the profile (same values, same order — so profile
    /// totals stay bitwise equal to `self.stats`). Tape replay calls this
    /// directly with the plan-time layer attribution.
    fn charge_at(
        &mut self,
        layer: Option<u16>,
        phase: Phase,
        detail: &'static str,
        cycles: u64,
        pj: f64,
        macs: u64,
    ) {
        if cycles == 0 && pj == 0.0 && macs == 0 {
            return;
        }
        if let Some(p) = self.profile.as_mut() {
            let start = self.stats.total_cycles();
            p.charge(layer, phase, detail, start, cycles, pj, macs);
        }
        match phase {
            Phase::Route => {
                self.stats.route_cycles += cycles;
                self.stats.route_pj += pj;
            }
            Phase::Compute => {
                self.stats.compute_cycles += cycles;
                self.stats.compute_pj += pj;
            }
            Phase::Host => {
                self.stats.host_cycles += cycles;
                self.stats.host_pj += pj;
            }
            Phase::Stream => {
                self.stats.stream_cycles += cycles;
                self.stats.stream_pj += pj;
            }
        }
        self.stats.macs += macs;
    }

    /// Validate and load a program; charges the one-time weight DMA when
    /// the network fits residency, else marks it streamed. Compiles the
    /// program into a resident [`ExecPlan`] for the fast path; programs
    /// the planner rejects run on the reference interpreter instead.
    ///
    /// Accepts `&Program` (clones once, as before), or an owned /
    /// `Arc<Program>` to load without copying.
    pub fn load(&mut self, program: impl IntoProgramArc) -> Result<()> {
        let program = program.into_program_arc();
        program.validate()?;
        let (weight_bits, streamed) = weight_residency(&program, &self.cfg)?;
        if !streamed {
            self.stats.load_pj += self.tech.dram_pj(weight_bits as usize)
                + self.tech.sram_write_pj(weight_bits as usize, self.cfg.pe_sram_bits);
        }
        // Plans are shared process-wide: N machines loading the same
        // program bytes on the same config pay exactly one plan build
        // (the reference-interpreter fallback on planner failure is
        // cached the same way).
        let exec = super::plan::cached_plan(&program, &self.cfg, &self.tech, streamed);
        self.plan = Some(LoadedProgram { program, weight_bits, streamed, exec });
        Ok(())
    }

    /// Load a program together with a pre-built shared [`ExecPlan`]
    /// (from [`super::plan::shared_plan`] or a model catalog) — skips
    /// even the cache lookup, so a fleet shard's load path does no plan
    /// work at all. `None` forces the reference-interpreter fallback.
    ///
    /// The plan carries the (fingerprint, machine) key it was built
    /// under; loading it onto a different program or machine errors here
    /// rather than mis-executing. Weight-DMA charging is identical to
    /// [`Apu::load`], so `SimStats`/`SimProfile` stay bitwise equal
    /// whether a plan was shared or built privately.
    pub fn load_with_plan(
        &mut self,
        program: impl IntoProgramArc,
        plan: Option<Arc<ExecPlan>>,
    ) -> Result<()> {
        let program = program.into_program_arc();
        program.validate()?;
        let (weight_bits, streamed) = weight_residency(&program, &self.cfg)?;
        if let Some(p) = plan.as_deref() {
            let key = super::plan::PlanKey::new(program.fingerprint(), &self.cfg);
            if p.key != key {
                bail!(
                    "shared plan mismatch: plan was built for fingerprint {:016x} on {} PEs, \
                     load target is fingerprint {:016x} on {} PEs",
                    p.key.fingerprint,
                    p.key.n_pes,
                    key.fingerprint,
                    key.n_pes
                );
            }
        }
        if !streamed {
            self.stats.load_pj += self.tech.dram_pj(weight_bits as usize)
                + self.tech.sram_write_pj(weight_bits as usize, self.cfg.pe_sram_bits);
        }
        self.plan = Some(LoadedProgram { program, weight_bits, streamed, exec: plan });
        Ok(())
    }

    /// Execute one inference over the loaded program.
    pub fn run(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let plan = self.plan.take().context("no program loaded")?;
        let result = if plan.exec.is_some() {
            self.run_planned(&plan, &[input])
                .map(|mut outs| outs.pop().expect("one output per input"))
        } else {
            self.run_inner(&plan, input)
        };
        self.plan = Some(plan);
        result
    }

    /// Execute a whole batch, layer-step by layer-step: each pre-decoded
    /// plan step runs across all lanes before the next (weights are
    /// resident or, when streamed, charged per inference via the tape —
    /// identical to `inputs.len()` sequential `run` calls, bitwise, in
    /// outputs, [`SimStats`] and [`SimProfile`]). Without a plan this
    /// falls back to exactly those sequential runs.
    ///
    /// One difference from sequential runs on the planned path: inputs
    /// are validated up front, so a bad length anywhere in the batch
    /// fails the whole batch before any charge.
    pub fn run_batch(&mut self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let plan = self.plan.take().context("no program loaded")?;
        let result = if plan.exec.is_some() {
            self.run_planned(&plan, inputs)
        } else {
            inputs.iter().map(|&input| self.run_inner(&plan, input)).collect()
        };
        self.plan = Some(plan);
        result
    }

    /// Execute one inference on the reference interpreter, bypassing the
    /// execution plan. The planner is cross-checked against this path.
    pub fn run_reference(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let plan = self.plan.take().context("no program loaded")?;
        let result = self.run_inner(&plan, input);
        self.plan = Some(plan);
        result
    }

    /// Whether the loaded program runs on the pre-decoded plan (vs. the
    /// interpreter fallback).
    pub fn is_planned(&self) -> bool {
        self.plan.as_ref().is_some_and(|p| p.exec.is_some())
    }

    /// Planned executor: run every batch lane through the pre-decoded
    /// steps — lanes partitioned across the lane-pool workers — then
    /// replay the charge tape once per inference on this thread.
    fn run_planned(&mut self, plan: &LoadedProgram, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let exec = plan.exec.as_ref().expect("run_planned without exec plan");
        let p = &plan.program;
        for input in inputs {
            if input.len() != p.din {
                bail!("input len {} != program din {}", input.len(), p.din);
            }
        }
        let n = inputs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        if self.streams.len() < n {
            self.streams.resize_with(n, StreamState::default);
        }
        for (st, input) in self.streams.iter_mut().zip(inputs) {
            st.acts.clear();
            st.acts.extend_from_slice(input);
            st.pending.clear();
            if st.partial.len() < exec.n_partial_slots {
                st.partial.resize_with(exec.n_partial_slots, Vec::new);
            }
        }
        // Partition the lanes into contiguous chunks, one scoped worker
        // per chunk, each with a private scratch and row counter. Lanes
        // are independent and per-lane math is identical under any
        // partition; the charge replay below stays on this thread in
        // lane order — so outputs, SimStats, and SimProfile are bitwise
        // identical for any thread count (1 thread spawns nothing).
        let (chunk, workers) = lane_pool::partition(n, self.opts.threads);
        if self.scratches.len() < workers {
            self.scratches.resize_with(workers, WaveScratch::default);
        }
        if self.worker_rows.len() < workers {
            self.worker_rows.resize_with(workers, Vec::new);
        }
        for rows in self.worker_rows.iter_mut().take(workers) {
            rows.clear();
            rows.resize(self.cfg.n_pes, 0);
        }
        let steps = exec.steps.as_slice();
        let lane_major = self.opts.lane_major_kernel;
        {
            let lanes = &mut self.streams[..n];
            let jobs: Vec<_> = lanes
                .chunks_mut(chunk)
                .zip(self.scratches.iter_mut())
                .zip(self.worker_rows.iter_mut())
                .map(|((lanes, scratch), rows)| {
                    move || super::plan::execute_steps(steps, lanes, scratch, rows, lane_major)
                })
                .collect();
            lane_pool::run(jobs);
        }
        for rows in self.worker_rows.iter().take(workers) {
            for (total, &r) in self.planned_rows.iter_mut().zip(rows) {
                *total += r;
            }
        }
        let ins = lane_pool::instruments();
        ins.workers.set(workers as f64);
        ins.steps.add(n as u64 * steps.len() as u64);
        // Replay the charge tape per inference: same values, same order
        // as the interpreter, so stats/profile stay bitwise identical.
        for _ in 0..n {
            for e in &exec.tape {
                self.charge_at(e.layer, e.phase, e.detail, e.cycles, e.pj, e.macs);
            }
            self.stats.inferences += 1;
            if let Some(pr) = self.profile.as_mut() {
                pr.count_inference();
            }
        }
        Ok(self.streams.iter_mut().take(n).map(|st| std::mem::take(&mut st.acts)).collect())
    }

    fn run_inner(&mut self, plan: &LoadedProgram, input: &[f32]) -> Result<Vec<f32>> {
        let p = &plan.program;
        if input.len() != p.din {
            bail!("input len {} != program din {}", input.len(), p.din);
        }
        self.acts = input.to_vec();
        self.act_owner = vec![u16::MAX; input.len()];
        self.pending.clear();
        self.pending_owner.clear();
        self.partial.clear();
        self.cur = None;

        for insn in &p.insns {
            match insn {
                Insn::ConfigLayer { layer, nb, bh, bw, bits, relu } => {
                    // New layer id commits the previous layer's waves.
                    if self.cur.as_ref().map(|c| c.layer_id) != Some(*layer) {
                        self.commit_pending();
                    }
                    let (nb, bh, bw) = (*nb as usize, *bh as usize, *bw as usize);
                    for pe in self.pes.iter_mut().take(nb) {
                        pe.configure(bh, bw, *bits as u32, *relu)?;
                    }
                    self.cur = Some(LayerCtx { layer_id: *layer, nb, bh, bw, bits: *bits as u32, scales_loaded: 0 });
                }
                Insn::LoadWeights { pe, seg } => {
                    let codes = p.segment(*seg)?.as_i8()?;
                    if plan.streamed {
                        // weights streamed from DRAM each run (folding dip)
                        let ctx = self.cur.as_ref().context("LoadWeights before ConfigLayer")?;
                        let bits = codes.len() * ctx.bits as usize;
                        let pj = self.tech.dram_pj(bits)
                            + self.tech.sram_write_pj(bits, self.cfg.pe_sram_bits);
                        // 64-bit DMA bus
                        self.charge(Phase::Stream, "weight-stream", (bits as u64).div_ceil(64), pj, 0);
                    }
                    let n = self.pes.len();
                    self.pes
                        .get_mut(*pe as usize)
                        .with_context(|| format!("PE {pe} out of range {n}"))?
                        .load_weights(codes)?;
                }
                Insn::LoadBias { pe, seg } => {
                    let b = p.segment(*seg)?.as_f32()?;
                    let n = self.pes.len();
                    self.pes
                        .get_mut(*pe as usize)
                        .with_context(|| format!("PE {pe} out of range {n}"))?
                        .load_bias(b)?;
                }
                Insn::SetScales { pe, seg } => {
                    let s = p.segment(*seg)?.as_f32()?;
                    if s.len() != 2 {
                        bail!("scales segment must be [w_scale, out_scale]");
                    }
                    let n = self.pes.len();
                    self.pes
                        .get_mut(*pe as usize)
                        .with_context(|| format!("PE {pe} out of range {n}"))?
                        .set_scales(s[0], s[1])?;
                    if let Some(c) = self.cur.as_mut() {
                        c.scales_loaded += 1;
                    }
                }
                Insn::Route { seg, from_input } => {
                    let routes = p.segment(*seg)?.as_routes()?;
                    self.route_phase(routes, *from_input)?;
                }
                Insn::Compute { rows } => self.compute_phase(*rows as usize)?,
                Insn::Scatter { seg, buf } => {
                    let perm = p.segment(*seg)?.as_u32()?;
                    self.scatter_phase(perm, *buf)?;
                }
                Insn::HostOp { op, seg } => {
                    self.commit_pending();
                    let params = p.segment(*seg)?.as_f32()?;
                    self.host_op(*op, params)?;
                }
                Insn::HostDense { w_seg, b_seg, relu } => {
                    self.commit_pending();
                    let w = p.segment(*w_seg)?.as_f32()?;
                    let b = p.segment(*b_seg)?.as_f32()?;
                    self.host_dense(w, b, *relu)?;
                }
                Insn::Halt => break,
            }
        }
        self.commit_pending();
        if !self.partial.is_empty() {
            let ids: Vec<u16> = self.partial.keys().copied().collect();
            bail!("program ended with unfolded partial buffer(s) {ids:?} (missing FoldAdd)");
        }
        self.stats.inferences += 1;
        if let Some(pr) = self.profile.as_mut() {
            pr.count_inference();
        }
        if self.acts.len() != p.dout {
            bail!("program produced {} outputs, expected {}", self.acts.len(), p.dout);
        }
        Ok(std::mem::take(&mut self.acts))
    }

    /// Commit accumulated wave scatters into the visible stream.
    fn commit_pending(&mut self) {
        if !self.pending.is_empty() {
            self.acts = std::mem::take(&mut self.pending);
            self.act_owner = std::mem::take(&mut self.pending_owner);
        }
    }

    /// Routing phase: drive the crossbar cycle by cycle from the static
    /// schedule. Sources are either the input stream (chunk blocks) or the
    /// previous layer's PE output SRAMs.
    fn route_phase(&mut self, routes: &[crate::sched::Assignment], from_input: bool) -> Result<()> {
        let ctx = self.cur.clone().context("Route before ConfigLayer")?;
        let bits = ctx.bits as usize;
        if ctx.scales_loaded < ctx.nb {
            bail!("Route before all {} PE scales loaded ({} done)", ctx.nb, ctx.scales_loaded);
        }
        for pe in self.pes.iter_mut().take(ctx.nb) {
            pe.clear_latch();
        }
        // Per-assignment energy is identical within a phase: hoist it.
        let src_read = if from_input {
            self.tech.dram_pj(bits)
        } else {
            self.tech.sram_read_pj(bits, (ctx.bh * bits).max(1))
        };
        let pj_per_route =
            src_read + self.tech.mux_pj_per_bit * bits as f64 + bits as f64 * self.tech.latch_pj_per_bit;
        let mut n_cycles = 0u32;
        let mut phase_pj = 0.0f64;
        let mut i = 0usize;
        // dst → slot scratch, tagged by cycle to avoid clearing (n_pes is small).
        let mut slot_of = vec![(u32::MAX, 0u32); self.cfg.n_pes];
        while i < routes.len() {
            let cycle = routes[i].cycle;
            self.crossbar.begin_cycle();
            let mut j = i;
            while j < routes.len() && routes[j].cycle == cycle {
                let a = routes[j];
                let act = a.act as usize;
                if act >= self.acts.len() {
                    bail!("route references activation {act} beyond buffer {}", self.acts.len());
                }
                if !from_input {
                    let owner = self.act_owner[act];
                    if owner != u16::MAX && owner != a.src % self.cfg.n_pes as u16 {
                        bail!("schedule says PE {} broadcasts act {act} but PE {owner} owns it", a.src);
                    }
                }
                let wire = a.src as usize % self.cfg.n_pes;
                self.crossbar.broadcast(wire, self.acts[act])?;
                self.crossbar.select(a.dst as usize, wire)?;
                slot_of[a.dst as usize] = (cycle, a.dst_slot);
                j += 1;
            }
            phase_pj += pj_per_route * (j - i) as f64;
            for (dst, value) in self.crossbar.end_cycle()? {
                let (tag, slot) = slot_of[dst];
                if tag != cycle {
                    bail!("latched PE {dst} missing slot");
                }
                self.pes[dst].latch_input(slot as usize, value)?;
            }
            n_cycles += 1;
            i = j;
        }
        self.charge(Phase::Route, "route", n_cycles as u64, phase_pj, 0);
        Ok(())
    }

    /// MAC phase: all nb PEs compute one output row per cycle in parallel.
    fn compute_phase(&mut self, rows: usize) -> Result<()> {
        let ctx = self.cur.clone().context("Compute before ConfigLayer")?;
        if rows != ctx.bh {
            bail!("Compute rows {rows} != configured bh {}", ctx.bh);
        }
        let pe_cfg = PeConfig { block_h: ctx.bh, block_w: ctx.bw, bits: ctx.bits };
        let per_cycle = pe_energy_per_cycle(&self.tech, &pe_cfg, PeMode::Spatial).total();
        for row in 0..rows {
            for pe in self.pes.iter_mut().take(ctx.nb) {
                pe.compute_row(row)?;
            }
        }
        self.charge(
            Phase::Compute,
            "compute",
            rows as u64,
            per_cycle * rows as f64 * ctx.nb as f64,
            (ctx.nb * ctx.bh * ctx.bw) as u64,
        );
        Ok(())
    }

    /// Publish PE outputs into a host output buffer. Segment layout:
    /// `[dout, perm...]` — `perm[g*bh + i]` is the global index of PE g's
    /// row-i output. `buf = 0` targets the layer's pending buffer;
    /// `buf >= 1` a named partial-sum buffer (§4.4.3-II column tiles)
    /// that a later `FoldAdd` consumes. Zero extra cycles: outputs
    /// physically stay in the PE output SRAMs (Fig. 5); this is
    /// compile-time knowledge.
    fn scatter_phase(&mut self, seg: &[u32], buf: u16) -> Result<()> {
        let ctx = self.cur.clone().context("Scatter before ConfigLayer")?;
        let (dout, perm) = seg.split_first().context("empty scatter segment")?;
        let dout = *dout as usize;
        if perm.len() != ctx.nb * ctx.bh {
            bail!("scatter perm len {} != {}x{}", perm.len(), ctx.nb, ctx.bh);
        }
        let (vals, owner) = if buf == 0 {
            if self.pending.is_empty() {
                self.pending = vec![0f32; dout];
                self.pending_owner = vec![u16::MAX; dout];
            } else if self.pending.len() != dout {
                bail!("wave scatter dout {dout} != pending {}", self.pending.len());
            }
            (&mut self.pending, &mut self.pending_owner)
        } else {
            let entry = self
                .partial
                .entry(buf)
                .or_insert_with(|| (vec![0f32; dout], vec![u16::MAX; dout]));
            if entry.0.len() != dout {
                bail!("wave scatter dout {dout} != partial buffer {buf} len {}", entry.0.len());
            }
            (&mut entry.0, &mut entry.1)
        };
        for g in 0..ctx.nb {
            for i in 0..ctx.bh {
                let global = perm[g * ctx.bh + i] as usize;
                if global >= dout {
                    bail!("scatter index {global} out of range {dout}");
                }
                if owner[global] != u16::MAX {
                    bail!("scatter writes activation {global} twice (buffer {buf})");
                }
                vals[global] = self.pes[g].output(i).context("missing PE output")?;
                owner[global] = g as u16;
            }
        }
        Ok(())
    }

    /// Non-MAC host-core ops (paper §4.4.3): charged per element.
    fn host_op(&mut self, op: HostOpKind, params: &[f32]) -> Result<()> {
        match op {
            HostOpKind::Relu => {
                for v in &mut self.acts {
                    *v = v.max(0.0);
                }
                self.charge_host("relu", self.acts.len());
            }
            HostOpKind::Quantize => {
                let scale = *params.first().context("Quantize needs [scale]")?;
                let bits = params.get(1).map(|&b| b as u32).unwrap_or(4);
                let q = Quantizer::new(bits, scale);
                for v in &mut self.acts {
                    *v = q.fake(*v);
                }
                self.act_owner = vec![u16::MAX; self.acts.len()];
                self.charge_host("quantize", self.acts.len());
            }
            HostOpKind::MaxPool => {
                let [h, w, c, win, stride] = params else {
                    bail!("MaxPool needs [h, w, c, window, stride]");
                };
                let (h, w, c, win, stride) =
                    (*h as usize, *w as usize, *c as usize, *win as usize, *stride as usize);
                let out = host_maxpool(&self.acts, h, w, c, win, stride)?;
                // Per-element charging like every other host op: each
                // output costs win² window loads plus win²−1 max-combines
                // (the reduction seed is register init, not a charged
                // op). The analytic model (`compiler::cost`) charges the
                // identical figure; the integration tests assert it.
                self.charge_host("maxpool", out.len() * (2 * win * win - 1));
                self.acts = out;
                self.act_owner = vec![u16::MAX; self.acts.len()];
            }
            HostOpKind::FoldAdd => {
                // Runtime-operand fold (§4.4.3-II): params select which
                // named partial buffer to fold; the operand values were
                // scattered by this run's PE tile waves.
                let &[src] = params else {
                    bail!("FoldAdd params must be [src_buf]");
                };
                if !src.is_finite() || src.fract() != 0.0 || src < 1.0 || src > u16::MAX as f32 {
                    bail!("FoldAdd buffer id {src} is not a valid partial buffer id");
                }
                let (vals, owner) = self
                    .partial
                    .remove(&(src as u16))
                    .with_context(|| format!("FoldAdd of missing partial buffer {src}"))?;
                if vals.len() != self.acts.len() {
                    bail!("FoldAdd buffer len {} != activation stream {}", vals.len(), self.acts.len());
                }
                if let Some(i) = owner.iter().position(|&o| o == u16::MAX) {
                    bail!("FoldAdd of incomplete partial buffer {src} (element {i} never scattered)");
                }
                for (v, p) in self.acts.iter_mut().zip(&vals) {
                    *v += p;
                }
                self.charge_host("fold-add", vals.len());
                // Folded values live on the host core now: no PE owns them.
                self.act_owner = vec![u16::MAX; self.acts.len()];
            }
            HostOpKind::Gather => {
                let mut out = Vec::with_capacity(params.len());
                for &idx in params {
                    // A NaN or fractional index would silently truncate
                    // (NaN casts to 0) and read the wrong element — fail
                    // loudly instead.
                    if !idx.is_finite() || idx.fract() != 0.0 {
                        bail!("Gather index {idx} is not a finite integral value");
                    }
                    // Negative index = implicit zero: the compiler uses
                    // this to materialize zero-padded conv input planes.
                    if idx < 0.0 {
                        out.push(0.0);
                        continue;
                    }
                    let i = idx as usize;
                    if i >= self.acts.len() {
                        bail!("Gather index {i} out of range");
                    }
                    out.push(self.acts[i]);
                }
                self.charge_host("gather", params.len());
                self.acts = out;
                self.act_owner = vec![u16::MAX; self.acts.len()];
            }
        }
        Ok(())
    }

    /// Small dense FC on the host core (1 MAC/cycle).
    fn host_dense(&mut self, w: &[f32], b: &[f32], relu: bool) -> Result<()> {
        let din = self.acts.len();
        let dout = b.len();
        if w.len() != dout * din {
            bail!("host dense: weight len {} != {dout}x{din}", w.len());
        }
        let mut out = vec![0f32; dout];
        for (r, o) in out.iter_mut().enumerate() {
            let mut acc = 0f32;
            let row = &w[r * din..(r + 1) * din];
            for (x, wv) in self.acts.iter().zip(row) {
                acc += x * wv;
            }
            *o = if relu { (acc + b[r]).max(0.0) } else { acc + b[r] };
        }
        let ops = dout * din;
        self.charge(Phase::Host, "dense", ops as u64, ops as f64 * self.tech.host_pj_per_op, ops as u64);
        self.acts = out;
        self.act_owner = vec![u16::MAX; self.acts.len()];
        Ok(())
    }

    fn charge_host(&mut self, detail: &'static str, ops: usize) {
        self.charge(Phase::Host, detail, ops as u64, ops as f64 * self.tech.host_pj_per_op, 0);
    }

    /// Resident weight footprint of the loaded program, bits.
    pub fn resident_weight_bits(&self) -> u64 {
        self.plan.as_ref().map(|p| p.weight_bits).unwrap_or(0)
    }

    /// Whether the loaded program streams weights per run.
    pub fn is_streamed(&self) -> bool {
        self.plan.as_ref().map(|p| p.streamed).unwrap_or(false)
    }
}

/// Channel-last max-pool — the functional semantics of
/// [`HostOpKind::MaxPool`]. Shared with the compiler pipeline's
/// reference forward (`compiler::pipeline`) so the oracle and the
/// executed host op cannot drift apart.
pub fn host_maxpool(
    acts: &[f32],
    h: usize,
    w: usize,
    c: usize,
    win: usize,
    stride: usize,
) -> Result<Vec<f32>> {
    if h * w * c != acts.len() {
        bail!("MaxPool shape {h}x{w}x{c} != buffer {}", acts.len());
    }
    if win == 0 || stride == 0 || win > h || win > w {
        bail!("MaxPool window {win}/stride {stride} invalid for {h}x{w}");
    }
    let oh = (h - win) / stride + 1;
    let ow = (w - win) / stride + 1;
    let mut out = vec![f32::NEG_INFINITY; oh * ow * c];
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut m = f32::NEG_INFINITY;
                for ky in 0..win {
                    for kx in 0..win {
                        let v = acts[((oy * stride + ky) * w + (ox * stride + kx)) * c + ch];
                        m = m.max(v);
                    }
                }
                out[(oy * ow + ox) * c + ch] = m;
            }
        }
    }
    Ok(out)
}

// Silence unused-import warning when DataSegment only appears in tests.
#[allow(unused_imports)]
use DataSegment as _DataSegmentUsed;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::emit::compile_packed_layers;
    use crate::pruning::{BlockStructure, PackedLayer};
    use crate::util::rng::Rng;

    /// Build a 2-layer packed network and an input.
    fn two_layer_fixture(seed: u64) -> (Vec<PackedLayer>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let s1 = BlockStructure::random(20, 16, 4, &mut rng).unwrap();
        let s2 = BlockStructure::random(12, 20, 4, &mut rng).unwrap();
        let mk = |s: &BlockStructure, rng: &mut Rng| {
            let w: Vec<f32> = (0..s.dout * s.din).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..s.dout).map(|_| rng.normal() * 0.1).collect();
            let os: Vec<f32> = (0..s.nb).map(|_| 0.2 + rng.f64() as f32 * 0.3).collect();
            PackedLayer::quantize_from(s.clone(), 4, &w, &b, os, true).unwrap()
        };
        let l1 = mk(&s1, &mut rng);
        let l2 = mk(&s2, &mut rng);
        let input: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        (vec![l1, l2], input)
    }

    fn reference_forward(layers: &[PackedLayer], input: &[f32], in_scale: f32) -> Vec<f32> {
        let inq = Quantizer::new(4, in_scale);
        let mut h: Vec<f32> = input.iter().map(|&x| inq.fake(x)).collect();
        for l in layers {
            h = l.forward(&h).unwrap();
        }
        h
    }

    #[test]
    fn simulated_network_matches_functional_reference() {
        let (layers, input) = two_layer_fixture(31);
        let in_scale = Quantizer::calibrate(4, &input).scale;
        let want = reference_forward(&layers, &input, in_scale);

        let program = compile_packed_layers("fixture", &layers, in_scale, 4, 4).unwrap();
        let mut apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        apu.load(&program).unwrap();
        let got = apu.run(&input).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-5, "output {i}: {g} vs {w}");
        }
        let st = apu.stats();
        assert!(st.route_cycles > 0 && st.compute_cycles > 0);
        assert_eq!(st.macs, (20 * 16 / 4 + 12 * 20 / 4) as u64); // density 1/4
        assert_eq!(st.inferences, 1);
        assert!(!apu.is_streamed());
    }

    #[test]
    fn folded_layer_matches_reference_on_fewer_pes() {
        // 4-block layers on a 2-PE machine: the compiler folds into waves.
        let (layers, input) = two_layer_fixture(35);
        let in_scale = Quantizer::calibrate(4, &input).scale;
        let want = reference_forward(&layers, &input, in_scale);

        let program = compile_packed_layers("fixture", &layers, in_scale, 4, 2).unwrap();
        let mut apu = Apu::new(ApuConfig { n_pes: 2, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        apu.load(&program).unwrap();
        let got = apu.run(&input).unwrap();
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-5, "output {i}: {g} vs {w}");
        }
        // folding serializes waves: more compute cycles than the 4-PE run
        let mut apu4 = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        let p4 = compile_packed_layers("fixture", &layers, in_scale, 4, 4).unwrap();
        apu4.load(&p4).unwrap();
        apu4.run(&input).unwrap();
        assert!(apu.stats().compute_cycles > apu4.stats().compute_cycles);
    }

    #[test]
    fn repeated_runs_accumulate_stats() {
        let (layers, input) = two_layer_fixture(32);
        let program = compile_packed_layers("fixture", &layers, 0.1, 4, 4).unwrap();
        let mut apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        apu.load(&program).unwrap();
        let a = apu.run(&input).unwrap();
        let cycles_one = apu.stats().total_cycles();
        let b = apu.run(&input).unwrap();
        assert_eq!(a, b); // deterministic
        assert_eq!(apu.stats().total_cycles(), 2 * cycles_one);
        assert_eq!(apu.stats().inferences, 2);
    }

    #[test]
    fn streamed_mode_charges_per_run() {
        let (layers, input) = two_layer_fixture(36);
        let program = compile_packed_layers("fixture", &layers, 0.1, 4, 2).unwrap();
        // PE SRAM big enough for one wave's block but not the whole net
        let mut apu = Apu::new(ApuConfig { n_pes: 2, pe_sram_bits: 100, clock_ghz: 1.0 });
        apu.load(&program).unwrap();
        assert!(apu.is_streamed());
        apu.run(&input).unwrap();
        let s1 = apu.stats().stream_pj;
        assert!(s1 > 0.0);
        apu.run(&input).unwrap();
        assert!((apu.stats().stream_pj - 2.0 * s1).abs() < 1e-9);
    }

    #[test]
    fn gather_rejects_non_integral_and_nan_indices() {
        let run_gather = |idx: Vec<f32>| -> Result<Vec<f32>> {
            let dout = idx.len();
            let mut p = Program { name: "g".into(), din: 2, dout, ..Default::default() };
            let seg = p.push_data(DataSegment::F32(idx));
            p.insns = vec![Insn::HostOp { op: HostOpKind::Gather, seg }, Insn::Halt];
            let mut apu = Apu::new(ApuConfig::default());
            apu.load(&p)?;
            apu.run(&[3.0, 4.0])
        };
        // negative = implicit zero stays supported; integral reads work
        assert_eq!(run_gather(vec![-1.0, 1.0]).unwrap(), vec![0.0, 4.0]);
        // fractional / NaN / infinite indices must fail instead of
        // silently truncating to the wrong element
        assert!(run_gather(vec![0.5, 1.0]).is_err());
        assert!(run_gather(vec![f32::NAN, 1.0]).is_err());
        assert!(run_gather(vec![f32::INFINITY, 1.0]).is_err());
    }

    #[test]
    fn maxpool_host_charge_counts_loads_and_combines() {
        // 4×4×1 plane, 2×2 window stride 2 → 4 outputs, each charged
        // win² loads + win²−1 max-combines = 7 host cycles.
        let mut p = Program { name: "mp".into(), din: 16, dout: 4, ..Default::default() };
        let seg = p.push_data(DataSegment::F32(vec![4.0, 4.0, 1.0, 2.0, 2.0]));
        p.insns = vec![Insn::HostOp { op: HostOpKind::MaxPool, seg }, Insn::Halt];
        let mut apu = Apu::new(ApuConfig::default());
        apu.load(&p).unwrap();
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        apu.run(&x).unwrap();
        assert_eq!(apu.stats().host_cycles, 4 * 7);
    }

    #[test]
    fn foldadd_requires_an_existing_partial_buffer() {
        let mut p = Program { name: "fa".into(), din: 2, dout: 2, ..Default::default() };
        let seg = p.push_data(DataSegment::F32(vec![1.0]));
        p.insns = vec![Insn::HostOp { op: HostOpKind::FoldAdd, seg }, Insn::Halt];
        let mut apu = Apu::new(ApuConfig::default());
        apu.load(&p).unwrap();
        let err = apu.run(&[1.0, 2.0]).unwrap_err();
        assert!(format!("{err:#}").contains("missing partial buffer"), "{err:#}");
    }

    #[test]
    fn zero_clock_and_empty_stats_never_produce_non_finite_figures() {
        let st = SimStats::default();
        assert_eq!(st.seconds(1.0), 0.0);
        assert_eq!(st.effective_gops(1.0), 0.0);
        assert_eq!(st.tops_per_watt(), 0.0);
        let mut busy = SimStats { compute_cycles: 100, macs: 50, ..Default::default() };
        assert_eq!(busy.seconds(0.0), 0.0);
        assert_eq!(busy.seconds(-1.0), 0.0);
        assert_eq!(busy.seconds(f64::NAN), 0.0);
        assert_eq!(busy.effective_gops(0.0), 0.0);
        assert_eq!(busy.tops_per_watt(), 0.0); // no energy charged yet
        busy.compute_pj = 25.0;
        assert!((busy.tops_per_watt() - 8.0).abs() < 1e-12); // 200 ops / 25 pJ
        assert!(busy.effective_gops(1.0).is_finite() && busy.effective_gops(1.0) > 0.0);
    }

    #[test]
    fn profile_mirrors_stats_exactly() {
        let (layers, input) = two_layer_fixture(33);
        let program = compile_packed_layers("fixture", &layers, 0.1, 4, 2).unwrap();
        // tiny SRAM: streamed mode, so weight-stream charges profile too
        let mut apu = Apu::new(ApuConfig { n_pes: 2, pe_sram_bits: 100, clock_ghz: 1.0 });
        apu.load(&program).unwrap();
        assert!(apu.profile().is_none()); // off by default
        apu.enable_profiling();
        apu.run(&input).unwrap();
        apu.run(&input).unwrap();
        let profile = apu.profile().unwrap();
        profile.check_against(apu.stats()).unwrap();
        assert!(profile.records().iter().any(|r| r.detail == "weight-stream"));
        assert_eq!(profile.totals().inferences, 2);
        // per-layer cycle totals partition the machine total exactly
        let cycle_sum: u64 = profile.by_layer().values().map(|a| a.total_cycles()).sum();
        assert_eq!(cycle_sum, apu.stats().total_cycles());
    }

    #[test]
    fn reset_stats_clears_profile_with_stats() {
        let (layers, input) = two_layer_fixture(37);
        let program = compile_packed_layers("fixture", &layers, 0.1, 4, 4).unwrap();
        let mut apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        apu.load(&program).unwrap();
        apu.enable_profiling();
        apu.run(&input).unwrap();
        assert!(!apu.profile().unwrap().is_empty());
        apu.reset_stats();
        assert!(apu.profile().unwrap().is_empty());
        apu.run(&input).unwrap();
        apu.profile().unwrap().check_against(apu.stats()).unwrap();
        // taking the profile detaches it and disables further mirroring
        let taken = apu.take_profile().unwrap();
        assert!(!taken.is_empty());
        assert!(apu.profile().is_none());
        assert!(apu.pe_rows_computed().iter().sum::<u64>() > 0);
    }

    /// Planned execution must be indistinguishable from the interpreter:
    /// bitwise-equal outputs, equal stats, equal profile records.
    fn assert_planned_matches_reference(cfg: ApuConfig, program: &Program, input: &[f32]) {
        let mut fast = Apu::new(cfg.clone());
        let mut refr = Apu::new(cfg);
        fast.load(program).unwrap();
        refr.load(program).unwrap();
        assert!(fast.is_planned(), "planner rejected a supported program");
        fast.enable_profiling();
        refr.enable_profiling();
        let got = fast.run(input).unwrap();
        let want = refr.run_reference(input).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "output {i}: {g} vs {w}");
        }
        assert_eq!(fast.stats(), refr.stats());
        assert_eq!(fast.profile().unwrap().records(), refr.profile().unwrap().records());
        fast.profile().unwrap().check_against(fast.stats()).unwrap();
        assert_eq!(fast.pe_rows_computed(), refr.pe_rows_computed());
    }

    #[test]
    fn planned_run_matches_reference_bitwise() {
        let (layers, input) = two_layer_fixture(41);
        let in_scale = Quantizer::calibrate(4, &input).scale;
        let program = compile_packed_layers("fixture", &layers, in_scale, 4, 4).unwrap();
        let cfg = ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 };
        assert_planned_matches_reference(cfg, &program, &input);
    }

    #[test]
    fn planned_folded_and_streamed_match_reference_bitwise() {
        let (layers, input) = two_layer_fixture(42);
        let program = compile_packed_layers("fixture", &layers, 0.1, 4, 2).unwrap();
        // folded waves, resident
        let cfg = ApuConfig { n_pes: 2, pe_sram_bits: 1 << 16, clock_ghz: 1.0 };
        assert_planned_matches_reference(cfg, &program, &input);
        // streamed: weight DMA charged per inference via the tape
        let cfg = ApuConfig { n_pes: 2, pe_sram_bits: 100, clock_ghz: 1.0 };
        let mut apu = Apu::new(cfg.clone());
        apu.load(&program).unwrap();
        assert!(apu.is_streamed() && apu.is_planned());
        assert_planned_matches_reference(cfg, &program, &input);
    }

    #[test]
    fn run_batch_equals_sequential_runs_bitwise() {
        let (layers, input) = two_layer_fixture(43);
        let program = compile_packed_layers("fixture", &layers, 0.1, 4, 4).unwrap();
        let mk = || {
            let mut a = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
            a.load(&program).unwrap();
            a.enable_profiling();
            a
        };
        let inputs: Vec<Vec<f32>> = (0..5)
            .map(|k| input.iter().map(|&x| x * (1.0 + k as f32 * 0.1)).collect())
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut batched = mk();
        let got = batched.run_batch(&refs).unwrap();
        let mut seq = mk();
        let want: Vec<Vec<f32>> = refs.iter().map(|&x| seq.run(x).unwrap()).collect();
        assert_eq!(got, want);
        assert_eq!(batched.stats(), seq.stats());
        assert_eq!(batched.stats().inferences, 5);
        assert_eq!(batched.profile().unwrap().records(), seq.profile().unwrap().records());
        // empty batch: no charges, no outputs
        let before = batched.stats().clone();
        assert!(batched.run_batch(&[]).unwrap().is_empty());
        assert_eq!(batched.stats(), &before);
    }

    #[test]
    fn exec_options_are_bitwise_invisible() {
        let (layers, input) = two_layer_fixture(45);
        let program = compile_packed_layers("fixture", &layers, 0.1, 4, 4).unwrap();
        let inputs: Vec<Vec<f32>> = (0..5)
            .map(|k| input.iter().map(|&x| x * (1.0 + k as f32 * 0.07)).collect())
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let run_with = |opts: ExecOptions| {
            let mut a = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
            a.load(&program).unwrap();
            a.enable_profiling();
            a.set_exec_options(opts);
            let out = a.run_batch(&refs).unwrap();
            let stats = a.stats().clone();
            let profile = a.take_profile().unwrap();
            (out, stats, profile, a.pe_rows_computed())
        };
        let (out, stats, profile, rows) = run_with(ExecOptions::default());
        let variants = [
            ExecOptions { threads: 2, lane_major_kernel: false },
            ExecOptions { threads: 4, lane_major_kernel: false },
            // more workers than lanes: degenerates to one lane each
            ExecOptions { threads: 16, lane_major_kernel: false },
            ExecOptions { threads: 1, lane_major_kernel: true },
            ExecOptions { threads: 3, lane_major_kernel: true },
        ];
        for opts in variants {
            let (o, s, p, r) = run_with(opts.clone());
            assert_eq!(o, out, "outputs differ under {opts:?}");
            assert_eq!(s, stats, "stats differ under {opts:?}");
            assert_eq!(p.records(), profile.records(), "profile differs under {opts:?}");
            assert_eq!(r, rows, "pe rows differ under {opts:?}");
        }
    }

    #[test]
    fn planner_falls_back_to_interpreter_on_unsupported_programs() {
        // FoldAdd of a never-created buffer: plan build fails, load still
        // succeeds, and run reports the interpreter's original error.
        let mut p = Program { name: "fa".into(), din: 2, dout: 2, ..Default::default() };
        let seg = p.push_data(DataSegment::F32(vec![1.0]));
        p.insns = vec![Insn::HostOp { op: HostOpKind::FoldAdd, seg }, Insn::Halt];
        let mut apu = Apu::new(ApuConfig::default());
        apu.load(&p).unwrap();
        assert!(!apu.is_planned());
        assert!(apu.run(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn load_accepts_owned_and_shared_programs() {
        let (layers, input) = two_layer_fixture(44);
        let program = compile_packed_layers("fixture", &layers, 0.1, 4, 4).unwrap();
        let cfg = ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 };
        let shared = std::sync::Arc::new(program.clone());
        let mut a = Apu::new(cfg.clone());
        a.load(std::sync::Arc::clone(&shared)).unwrap(); // Arc: no copy
        let mut b = Apu::new(cfg.clone());
        b.load(&shared).unwrap(); // &Arc
        let mut c = Apu::new(cfg);
        c.load(program).unwrap(); // owned: no copy
        let x = a.run(&input).unwrap();
        assert_eq!(x, b.run(&input).unwrap());
        assert_eq!(x, c.run(&input).unwrap());
    }

    #[test]
    fn rejects_wrong_input_len() {
        let (layers, _) = two_layer_fixture(34);
        let program = compile_packed_layers("fixture", &layers, 0.1, 4, 4).unwrap();
        let mut apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        apu.load(&program).unwrap();
        assert!(apu.run(&[0.0; 3]).is_err());
    }

    #[test]
    fn run_without_load_fails() {
        let mut apu = Apu::new(ApuConfig::default());
        assert!(apu.run(&[0.0; 8]).is_err());
    }
}
