//! Cycle-accurate APU simulator (the paper's C++ RTL simulator substitute,
//! §4.2 Fig. 8).
//!
//! Executes [`crate::isa::Program`]s over a parameterized machine: an
//! array of spatial PEs (Fig. 4a datapath), the output-multiplexed
//! crossbar (Fig. 5), and a host-core model servicing the RoCC command
//! stream (non-MAC ops, DMA, folding adds). Every cycle is accounted —
//! routing, compute, and host phases — and every access is charged energy
//! through [`crate::hwmodel`], so a simulation yields both the numerics
//! (validated against the PJRT golden model) and the performance/energy
//! numbers the paper reports.

pub mod apu;
mod lane_pool;
pub mod pe;
pub mod plan;
pub mod profile;

pub use apu::{host_maxpool, Apu, ApuConfig, ExecOptions, IntoProgramArc, SimStats};
pub use pe::PeUnit;
pub use plan::{
    export_plan_cache_metrics, plan_cache_builds, plan_cache_stats, shared_plan, ExecPlan,
    PlanCacheStats,
};
pub use profile::{Phase, PhaseRecord, SimProfile};
