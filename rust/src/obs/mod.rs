//! Unified observability layer: metrics, tracing, and (together with
//! [`crate::sim::SimProfile`]) cycle/energy profiling.
//!
//! Three cooperating layers make the stack's behavior visible without
//! changing it:
//!
//! * [`metrics`] — a process-wide registry of counters, gauges, and
//!   fixed-bucket histograms (atomics only, no deps) with Prometheus text
//!   exposition and a JSON dump. The fleet's shard workers, batcher, and
//!   SLO reporter register into it; `apu fleet --metrics-out` dumps it at
//!   shutdown.
//! * [`trace`] — span/event tracing exported as Chrome trace-event JSON
//!   (`chrome://tracing` / Perfetto loadable). Fleet requests record
//!   their enqueue→dequeue→batch-assembly→engine-run→reply lifecycle,
//!   and compiler passes record per-pass spans.
//! * simulator profiling — `Apu::enable_profiling` mirrors every cycle
//!   and picojoule charge into a per-layer [`crate::sim::SimProfile`]
//!   whose totals are provably identical to `SimStats`; `apu profile`
//!   prints the breakdown and writes the Chrome trace.
//!
//! The paper's headline (18 TOPS/W from minimized data movement) is only
//! auditable with this substrate: per-layer profiles show where cycles
//! and pJ actually go, and per-request traces show where latency goes.

pub mod metrics;
pub mod trace;

pub use metrics::{Counter, Gauge, Histogram, Registry};
pub use trace::{chrome_trace_json, TraceEvent, Tracer, PID_COMPILER, PID_FLEET, PID_SIM};
