//! Span/event tracing with Chrome trace-event export.
//!
//! A [`Tracer`] is a clock epoch plus a shared event buffer; producers
//! stamp microsecond timestamps with [`Tracer::now_us`] and push complete
//! `ph:"X"` duration spans. Fleet requests record their
//! enqueue→dequeue→batch-assembly→engine-run→reply lifecycle, compiler
//! passes record one span each, and the simulator's
//! [`crate::sim::SimProfile`] converts cycle records into the same event
//! shape. [`chrome_trace_json`] serializes any event list into the JSON
//! object format that `chrome://tracing` / Perfetto load directly, with
//! events sorted by timestamp. Process lanes: [`PID_FLEET`],
//! [`PID_COMPILER`], [`PID_SIM`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Trace-viewer process lane for fleet/serving spans.
pub const PID_FLEET: u32 = 0;
/// Trace-viewer process lane for compiler pass spans.
pub const PID_COMPILER: u32 = 1;
/// Trace-viewer process lane for simulator cycle records.
pub const PID_SIM: u32 = 2;

/// One complete-duration span (`ph:"X"` in the trace-event format).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    pub cat: String,
    pub pid: u32,
    pub tid: u64,
    /// Start timestamp, microseconds since the tracer epoch.
    pub ts_us: f64,
    /// Duration, microseconds.
    pub dur_us: f64,
    /// Extra `args` shown in the viewer's detail pane.
    pub args: Vec<(String, Json)>,
}

#[derive(Debug)]
struct TracerInner {
    epoch: Instant,
    events: Mutex<Vec<TraceEvent>>,
    next_id: AtomicU64,
}

/// Shared handle onto one trace buffer; clones record into the same
/// buffer with timestamps off the same epoch.
#[derive(Debug, Clone)]
pub struct Tracer(Arc<TracerInner>);

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer(Arc::new(TracerInner {
            epoch: Instant::now(),
            events: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
        }))
    }

    /// Microseconds since this tracer's epoch.
    pub fn now_us(&self) -> f64 {
        self.0.epoch.elapsed().as_secs_f64() * 1e6
    }

    /// Fresh id for correlating spans of one logical request.
    pub fn next_id(&self) -> u64 {
        self.0.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn record(&self, ev: TraceEvent) {
        self.0.events.lock().unwrap().push(ev);
    }

    /// Record a complete span with explicit start/duration.
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &self,
        name: &str,
        cat: &str,
        pid: u32,
        tid: u64,
        ts_us: f64,
        dur_us: f64,
        args: Vec<(String, Json)>,
    ) {
        self.record(TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            pid,
            tid,
            ts_us,
            dur_us,
            args,
        });
    }

    /// Start timestamp for an [`Tracer::end_span`] pair.
    pub fn begin(&self) -> f64 {
        self.now_us()
    }

    /// Record a span from `t0_us` (from [`Tracer::begin`]) to now.
    pub fn end_span(
        &self,
        name: &str,
        cat: &str,
        pid: u32,
        tid: u64,
        t0_us: f64,
        args: Vec<(String, Json)>,
    ) {
        let now = self.now_us();
        self.span(name, cat, pid, tid, t0_us, (now - t0_us).max(0.0), args);
    }

    pub fn len(&self) -> usize {
        self.0.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the recorded events.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.0.events.lock().unwrap().clone()
    }

    /// Append externally produced events (e.g. simulator cycle records)
    /// into this trace.
    pub fn extend(&self, evs: Vec<TraceEvent>) {
        self.0.events.lock().unwrap().extend(evs);
    }

    pub fn chrome_trace(&self) -> Json {
        chrome_trace_json(&self.events())
    }

    pub fn write_chrome_trace(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.chrome_trace().pretty())
    }
}

/// Serialize events as a Chrome trace-event JSON object
/// (`{"traceEvents": [...], "displayTimeUnit": "ms"}`), sorted by start
/// timestamp so the output is deterministic for a given event set.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let mut evs: Vec<&TraceEvent> = events.iter().collect();
    evs.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us).then(a.name.cmp(&b.name)));
    let arr = evs.into_iter().map(|e| {
        let mut pairs = vec![
            ("name", Json::str(e.name.clone())),
            ("cat", Json::str(e.cat.clone())),
            ("ph", Json::str("X")),
            ("pid", Json::Int(e.pid as i64)),
            ("tid", Json::Int(e.tid as i64)),
            ("ts", Json::num(e.ts_us)),
            ("dur", Json::num(e.dur_us)),
        ];
        if !e.args.is_empty() {
            let obj = e.args.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
            pairs.push(("args", Json::Obj(obj)));
        }
        Json::obj(pairs)
    });
    Json::obj(vec![("traceEvents", Json::arr(arr)), ("displayTimeUnit", Json::str("ms"))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_accumulate_and_snapshot() {
        let tr = Tracer::new();
        assert!(tr.is_empty());
        let t0 = tr.begin();
        tr.end_span("work", "test", PID_FLEET, 3, t0, vec![("k".to_string(), Json::Int(1))]);
        tr.span("fixed", "test", PID_SIM, 0, 10.0, 5.0, Vec::new());
        assert_eq!(tr.len(), 2);
        let evs = tr.events();
        assert_eq!(evs[0].name, "work");
        assert_eq!(evs[0].tid, 3);
        assert!(evs[0].dur_us >= 0.0);
    }

    #[test]
    fn clones_share_the_buffer_and_ids() {
        let tr = Tracer::new();
        let tr2 = tr.clone();
        assert_eq!(tr.next_id(), 0);
        assert_eq!(tr2.next_id(), 1);
        tr2.span("a", "c", 0, 0, 0.0, 1.0, Vec::new());
        assert_eq!(tr.len(), 1);
    }

    #[test]
    fn chrome_trace_sorts_by_timestamp() {
        let tr = Tracer::new();
        tr.span("late", "c", 0, 0, 30.0, 1.0, Vec::new());
        tr.span("early", "c", 0, 0, 10.0, 1.0, Vec::new());
        tr.span("mid", "c", 0, 0, 20.0, 1.0, Vec::new());
        let j = tr.chrome_trace();
        let evs = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        let names: Vec<&str> =
            evs.iter().map(|e| e.get("name").and_then(Json::as_str).unwrap()).collect();
        assert_eq!(names, vec!["early", "mid", "late"]);
        let ts: Vec<f64> =
            evs.iter().map(|e| e.get("ts").and_then(Json::as_f64).unwrap()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn chrome_trace_round_trips_with_escaping() {
        let tr = Tracer::new();
        tr.span(
            "quote \" backslash \\ newline \n",
            "cat",
            PID_COMPILER,
            7,
            1.5,
            2.25,
            vec![("detail".to_string(), Json::str("a\"b"))],
        );
        let text = tr.chrome_trace().pretty();
        let back = Json::parse(&text).unwrap();
        let ev = &back.get("traceEvents").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(ev.get("name").and_then(Json::as_str), Some("quote \" backslash \\ newline \n"));
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(ev.get("pid").and_then(Json::as_i64), Some(PID_COMPILER as i64));
        assert_eq!(ev.get("ts").and_then(Json::as_f64), Some(1.5));
        assert_eq!(ev.get("dur").and_then(Json::as_f64), Some(2.25));
        assert_eq!(ev.path("args/detail").and_then(Json::as_str), Some("a\"b"));
        assert_eq!(back.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    }

    #[test]
    fn empty_args_are_omitted() {
        let tr = Tracer::new();
        tr.span("bare", "c", 0, 0, 0.0, 1.0, Vec::new());
        let j = tr.chrome_trace();
        let ev = &j.get("traceEvents").and_then(Json::as_arr).unwrap()[0];
        assert!(ev.get("args").is_none());
    }
}
