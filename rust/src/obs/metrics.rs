//! Process-wide metrics registry: counters, gauges, and fixed-bucket
//! histograms over plain atomics (no external deps, no background
//! threads).
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc` clones
//! of the registered slot, so hot paths (shard workers, the batcher) hold
//! their handles and update lock-free; the [`Registry`] mutex is touched
//! only at registration and exposition time. Exposition comes in two
//! flavors: Prometheus text format ([`Registry::render_prometheus`],
//! spec-shaped HELP/TYPE headers, escaped label values, cumulative `le`
//! buckets) and a JSON dump ([`Registry::to_json`]) for offline diffing.
//! Series are keyed by sorted label sets in `BTreeMap`s, so both
//! expositions are deterministic.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Monotonically increasing event count. Cloning shares the underlying
/// atomic cell.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-written f64 value (stored as bits in an `AtomicU64`).
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    pub fn add(&self, d: f64) {
        atomic_f64_add(&self.0, d);
    }
}

/// Lock-free compare-exchange add on an f64 stored as bits.
fn atomic_f64_add(cell: &AtomicU64, d: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + d).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

#[derive(Debug)]
struct HistogramCore {
    /// Inclusive upper bounds, ascending; an implicit `+Inf` bucket
    /// follows (`counts.len() == bounds.len() + 1`).
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    sum_bits: AtomicU64,
    count: AtomicU64,
}

/// Fixed-bucket histogram. Like [`crate::util::stats::Summary`], non-finite
/// observations are dropped rather than propagated.
#[derive(Debug, Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    fn with_bounds(bounds: &[f64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]) && bounds.iter().all(|b| b.is_finite()),
            "histogram bounds must be finite and strictly ascending"
        );
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram(Arc::new(HistogramCore {
            bounds: bounds.to_vec(),
            counts,
            sum_bits: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }))
    }

    pub fn observe(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        // Number of bounds strictly below v == index of the first bucket
        // whose `le` bound admits v.
        let idx = self.0.bounds.partition_point(|&b| v > b);
        self.0.counts[idx].fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&self.0.sum_bits, v);
        self.0.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Cumulative `(le, count)` pairs, Prometheus-style: the final entry is
    /// `(+Inf, total)`.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.0.counts.len());
        for (i, c) in self.0.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            let le = self.0.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((le, acc));
        }
        out
    }
}

#[derive(Debug, Clone)]
enum Slot {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Slot {
    fn kind(&self) -> &'static str {
        match self {
            Slot::Counter(_) => "counter",
            Slot::Gauge(_) => "gauge",
            Slot::Histogram(_) => "histogram",
        }
    }
}

type LabelSet = Vec<(String, String)>;

#[derive(Debug)]
struct Family {
    help: String,
    kind: &'static str,
    series: BTreeMap<LabelSet, Slot>,
}

/// Registry of metric families. Registration is idempotent: asking for the
/// same `(name, labels)` returns a handle onto the same slot, so modules
/// can re-register without coordinating. Registering an existing name with
/// a different metric kind panics — that is a naming bug, not a runtime
/// condition.
#[derive(Debug, Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        match self.slot(name, help, "counter", labels, || Slot::Counter(Counter::default())) {
            Slot::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.slot(name, help, "gauge", labels, || Slot::Gauge(Gauge::default())) {
            Slot::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        bounds: &[f64],
        labels: &[(&str, &str)],
    ) -> Histogram {
        let make = || Slot::Histogram(Histogram::with_bounds(bounds));
        match self.slot(name, help, "histogram", labels, make) {
            Slot::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    fn slot(
        &self,
        name: &str,
        help: &str,
        kind: &'static str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Slot,
    ) -> Slot {
        let mut fams = self.families.lock().unwrap();
        let fam = fams
            .entry(name.to_string())
            .or_insert_with(|| Family { help: help.to_string(), kind, series: BTreeMap::new() });
        assert_eq!(
            fam.kind, kind,
            "metric {name} already registered as a {} (asked for {kind})",
            fam.kind
        );
        let slot = fam.series.entry(label_set(labels)).or_insert_with(make);
        debug_assert_eq!(slot.kind(), kind);
        slot.clone()
    }

    /// Read back a counter series; 0 if the series was never registered.
    pub fn counter_value(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        let fams = self.families.lock().unwrap();
        match fams.get(name).and_then(|f| f.series.get(&label_set(labels))) {
            Some(Slot::Counter(c)) => c.get(),
            _ => 0,
        }
    }

    /// Sum of a counter family across all label sets.
    pub fn counter_total(&self, name: &str) -> u64 {
        let fams = self.families.lock().unwrap();
        fams.get(name)
            .map(|f| {
                f.series
                    .values()
                    .map(|s| match s {
                        Slot::Counter(c) => c.get(),
                        _ => 0,
                    })
                    .sum()
            })
            .unwrap_or(0)
    }

    /// Read back a gauge series.
    pub fn gauge_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let fams = self.families.lock().unwrap();
        match fams.get(name).and_then(|f| f.series.get(&label_set(labels))) {
            Some(Slot::Gauge(g)) => Some(g.get()),
            _ => None,
        }
    }

    /// Prometheus text exposition format (one HELP/TYPE header per family,
    /// escaped label values, cumulative `le` buckets ending at `+Inf`).
    pub fn render_prometheus(&self) -> String {
        let fams = self.families.lock().unwrap();
        let mut out = String::new();
        for (name, fam) in fams.iter() {
            out.push_str("# HELP ");
            out.push_str(name);
            out.push(' ');
            out.push_str(&escape_help(&fam.help));
            out.push('\n');
            out.push_str("# TYPE ");
            out.push_str(name);
            out.push(' ');
            out.push_str(fam.kind);
            out.push('\n');
            for (labels, slot) in &fam.series {
                match slot {
                    Slot::Counter(c) => {
                        out.push_str(name);
                        push_labels(&mut out, labels, None);
                        out.push(' ');
                        out.push_str(&c.get().to_string());
                        out.push('\n');
                    }
                    Slot::Gauge(g) => {
                        out.push_str(name);
                        push_labels(&mut out, labels, None);
                        out.push(' ');
                        out.push_str(&fmt_value(g.get()));
                        out.push('\n');
                    }
                    Slot::Histogram(h) => {
                        for (le, n) in h.cumulative_buckets() {
                            out.push_str(name);
                            out.push_str("_bucket");
                            push_labels(&mut out, labels, Some(("le", &fmt_bound(le))));
                            out.push(' ');
                            out.push_str(&n.to_string());
                            out.push('\n');
                        }
                        out.push_str(name);
                        out.push_str("_sum");
                        push_labels(&mut out, labels, None);
                        out.push(' ');
                        out.push_str(&fmt_value(h.sum()));
                        out.push('\n');
                        out.push_str(name);
                        out.push_str("_count");
                        push_labels(&mut out, labels, None);
                        out.push(' ');
                        out.push_str(&h.count().to_string());
                        out.push('\n');
                    }
                }
            }
        }
        out
    }

    /// JSON dump of every family and series (for `--metrics-out x.json`
    /// and offline diffing).
    pub fn to_json(&self) -> Json {
        let fams = self.families.lock().unwrap();
        let mut top = BTreeMap::new();
        for (name, fam) in fams.iter() {
            let mut series = Vec::new();
            for (labels, slot) in &fam.series {
                let lbl = Json::Obj(
                    labels.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
                );
                series.push(match slot {
                    Slot::Counter(c) => {
                        Json::obj(vec![("labels", lbl), ("value", Json::Int(c.get() as i64))])
                    }
                    Slot::Gauge(g) => {
                        Json::obj(vec![("labels", lbl), ("value", Json::num(g.get()))])
                    }
                    Slot::Histogram(h) => Json::obj(vec![
                        ("labels", lbl),
                        ("count", Json::Int(h.count() as i64)),
                        ("sum", Json::num(h.sum())),
                        (
                            "buckets",
                            Json::arr(h.cumulative_buckets().into_iter().map(|(le, n)| {
                                Json::obj(vec![
                                    ("le", Json::str(fmt_bound(le))),
                                    ("count", Json::Int(n as i64)),
                                ])
                            })),
                        ),
                    ]),
                });
            }
            top.insert(
                name.clone(),
                Json::obj(vec![
                    ("help", Json::str(fam.help.clone())),
                    ("kind", Json::str(fam.kind)),
                    ("series", Json::Arr(series)),
                ]),
            );
        }
        Json::Obj(top)
    }
}

fn label_set(labels: &[(&str, &str)]) -> LabelSet {
    let mut ls: LabelSet = labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    ls.sort();
    ls
}

fn push_labels(out: &mut String, labels: &LabelSet, extra: Option<(&str, &str)>) {
    if labels.is_empty() && extra.is_none() {
        return;
    }
    out.push('{');
    let mut first = true;
    for (k, v) in labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).chain(extra) {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(k);
        out.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => out.push_str("\\\\"),
                '"' => out.push_str("\\\""),
                '\n' => out.push_str("\\n"),
                c => out.push(c),
            }
        }
        out.push('"');
    }
    out.push('}');
}

fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Format a bucket bound the way Prometheus clients do: integral bounds
/// without a trailing `.0`, `+Inf` for the overflow bucket.
fn fmt_bound(b: f64) -> String {
    if b.is_infinite() {
        "+Inf".to_string()
    } else if b.fract() == 0.0 && b.abs() < 1e15 {
        format!("{b:.0}")
    } else {
        format!("{b}")
    }
}

/// The process-wide registry (what `apu fleet --metrics-out` dumps).
/// Library code takes `&Registry`/`Arc<Registry>` so tests can use private
/// registries; binaries default to this one.
pub fn global() -> Arc<Registry> {
    static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(Registry::new())).clone()
}

/// Default request-latency buckets, microseconds (50µs … 100ms).
pub fn latency_buckets_us() -> Vec<f64> {
    vec![50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0, 25000.0, 50000.0, 100000.0]
}

/// Default batch-size buckets (powers of two up to the fleet's max batch).
pub fn batch_buckets() -> Vec<f64> {
    vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0]
}

/// Cache-hit latency buckets, microseconds. Hits skip batching and the
/// engine entirely, so they land orders of magnitude below
/// [`latency_buckets_us`] — these resolve the 1µs–1ms range instead.
pub fn cache_latency_buckets_us() -> Vec<f64> {
    vec![1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 1000.0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("reqs_total", "requests", &[("shard", "0")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // re-registration returns a handle onto the same cell
        let c2 = r.counter("reqs_total", "requests", &[("shard", "0")]);
        c2.inc();
        assert_eq!(c.get(), 6);
        assert_eq!(r.counter_value("reqs_total", &[("shard", "0")]), 6);
        assert_eq!(r.counter_value("reqs_total", &[("shard", "1")]), 0);

        let g = r.gauge("depth", "queue depth", &[]);
        g.set(3.5);
        g.add(1.0);
        assert_eq!(g.get(), 4.5);
        assert_eq!(r.gauge_value("depth", &[]), Some(4.5));
    }

    #[test]
    fn counter_total_sums_label_sets() {
        let r = Registry::new();
        r.counter("done", "d", &[("shard", "0")]).add(2);
        r.counter("done", "d", &[("shard", "1")]).add(3);
        assert_eq!(r.counter_total("done"), 5);
        assert_eq!(r.counter_total("missing"), 0);
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let h = Histogram::with_bounds(&[1.0, 5.0, 10.0]);
        for v in [0.5, 1.0, 2.0, 7.0, 100.0, f64::NAN, f64::INFINITY] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5); // non-finite dropped
        assert!((h.sum() - 110.5).abs() < 1e-9);
        let b = h.cumulative_buckets();
        // le=1 admits 0.5 and 1.0 (inclusive bound); cumulative thereafter
        assert_eq!(b, vec![(1.0, 2), (5.0, 3), (10.0, 4), (f64::INFINITY, 5)]);
        // cumulative counts never decrease and +Inf equals the total
        assert!(b.windows(2).all(|w| w[0].1 <= w[1].1));
        assert_eq!(b.last().unwrap().1, h.count());
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("m", "m", &[]);
        r.gauge("m", "m", &[]);
    }

    #[test]
    fn prometheus_exposition_format() {
        let r = Registry::new();
        r.counter("apu_reqs_total", "total requests", &[("shard", "0")]).add(7);
        r.gauge("apu_depth", "queue depth", &[]).set(2.0);
        let h = r.histogram("apu_lat_us", "latency", &[10.0, 100.0], &[("shard", "0")]);
        h.observe(5.0);
        h.observe(50.0);
        h.observe(500.0);
        let text = r.render_prometheus();
        assert!(text.contains("# HELP apu_reqs_total total requests\n"));
        assert!(text.contains("# TYPE apu_reqs_total counter\n"));
        assert!(text.contains("apu_reqs_total{shard=\"0\"} 7\n"));
        assert!(text.contains("apu_depth 2\n"));
        assert!(text.contains("apu_lat_us_bucket{shard=\"0\",le=\"10\"} 1\n"));
        assert!(text.contains("apu_lat_us_bucket{shard=\"0\",le=\"100\"} 2\n"));
        assert!(text.contains("apu_lat_us_bucket{shard=\"0\",le=\"+Inf\"} 3\n"));
        assert!(text.contains("apu_lat_us_sum{shard=\"0\"} 555\n"));
        assert!(text.contains("apu_lat_us_count{shard=\"0\"} 3\n"));
        // HELP/TYPE emitted once per family, not per series
        assert_eq!(text.matches("# TYPE apu_lat_us histogram").count(), 1);
    }

    #[test]
    fn label_values_are_escaped() {
        let r = Registry::new();
        r.counter("m", "help with \\ backslash\nand newline", &[("k", "a\"b\\c\nd")]).inc();
        let text = r.render_prometheus();
        assert!(text.contains("# HELP m help with \\\\ backslash\\nand newline\n"));
        assert!(text.contains("m{k=\"a\\\"b\\\\c\\nd\"} 1\n"));
    }

    #[test]
    fn json_dump_parses_back() {
        let r = Registry::new();
        r.counter("c", "counter", &[("shard", "1")]).add(3);
        r.gauge("g", "gauge", &[]).set(1.25);
        r.histogram("h", "hist", &[2.0], &[]).observe(1.0);
        let dump = r.to_json();
        let back = Json::parse(&dump.pretty()).unwrap();
        assert_eq!(back.path("c/series/0/value").and_then(Json::as_i64), Some(3));
        assert_eq!(back.path("c/series/0/labels/shard").and_then(Json::as_str), Some("1"));
        assert_eq!(back.path("g/series/0/value").and_then(Json::as_f64), Some(1.25));
        assert_eq!(back.path("h/series/0/buckets/1/le").and_then(Json::as_str), Some("+Inf"));
        assert_eq!(back.path("h/series/0/count").and_then(Json::as_i64), Some(1));
    }

    #[test]
    fn global_registry_is_shared() {
        let a = global();
        let b = global();
        a.counter("obs_selftest_total", "self test", &[]).inc();
        assert!(b.counter_total("obs_selftest_total") >= 1);
    }

    #[test]
    fn default_bucket_sets_are_ascending() {
        for b in [latency_buckets_us(), batch_buckets(), cache_latency_buckets_us()] {
            assert!(b.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
