//! `apu` — the framework CLI.
//!
//! ```text
//! apu figures <fig3|fig4b|fig6|fig9|fig10|fig11|fig13|fig14|fig15|headline|all>
//! apu compile   [--net artifact|lenet|alexnet[-nano]|vgg19|resnet50|vgg-nano|mha]
//!               [--machine paper|nano] [--seed S] [--out FILE] [--emit-asm]
//!               [--pes N] [--artifacts DIR]
//! apu simulate  [--pes N] [--n N] [--artifacts DIR]
//! apu profile   [--net <zoo>] [--machine paper|nano] [--seed S] [--runs N]
//!               [--threads T] [--trace-out FILE]
//! apu serve     [--engine sim|golden] [--requests N] [--rate RPS] [--batch B]
//! apu fleet     [--shards N] [--policy rr|lo|jsq] [--requests N] [--rate RPS]
//!               [--batch B] [--queue-cap Q] [--model synthetic|artifact|zoo:<name>]
//!               [--models zoo:a,zoo:b,prog.apu [--mix 70,20,10]] [--threads T]
//!               [--cache ENTRIES | --no-cache]
//!               [--metrics-out FILE] [--trace-out FILE]
//! apu dse       [--sweep block|precision]
//! apu netlist   [--pes N] [--block S] [--bits B]
//! ```

use anyhow::{bail, Context, Result};

use apu::compiler::{
    compile_packed_layers, import_bundle, pipeline, synthetic_packed_network, CostModel,
    PipelineOptions,
};
use apu::coordinator::{
    ApuEngine, BatchPolicy, DispatchPolicy, Fleet, FleetConfig, GoldenEngine, InputPool,
    ModelCatalog, ModelId, Reply, Server, SloReport, SubmitError, SyntheticLoad,
};
use apu::figures;
use apu::generator::{DesignInstance, GeneratorConfig};
use apu::obs::metrics;
use apu::obs::trace::Tracer;
use apu::runtime::Manifest;
use apu::sim::{Apu, ApuConfig};
use apu::util::bundle::Bundle;
use apu::util::cli::{parse, usage, Opt};
use apu::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let rest = &argv[1.min(argv.len())..];
    match cmd {
        "figures" => cmd_figures(rest),
        "compile" => cmd_compile(rest),
        "simulate" => cmd_simulate(rest),
        "profile" => cmd_profile(rest),
        "serve" => cmd_serve(rest),
        "fleet" => cmd_fleet(rest),
        "dse" => cmd_dse(rest),
        "netlist" => cmd_netlist(rest),
        _ => {
            println!(
                "apu — Tuning Algorithms and Generators for Efficient Edge Inference (reproduction)\n\n\
                 Commands:\n\
                 \x20 figures <id|all>   regenerate paper tables/figures\n\
                 \x20 compile            compile a network (zoo or trained artifact) to an APU program\n\
                 \x20 simulate           run the cycle-accurate simulator on the test vectors\n\
                 \x20 profile            per-layer cycle/energy breakdown of a zoo network\n\
                 \x20 serve              run the edge-serving coordinator demo\n\
                 \x20 fleet              run the sharded multi-engine serving fleet\n\
                 \x20 dse                design-space exploration sweeps (Figs. 10/11)\n\
                 \x20 netlist            print a generated design instance's structure\n"
            );
            Ok(())
        }
    }
}

fn cmd_figures(argv: &[String]) -> Result<()> {
    let which = argv.first().map(String::as_str).unwrap_or("all");
    let show = |id: &str| -> Result<()> {
        println!("== {id} ==");
        match id {
            "fig3" => println!("{}", figures::fig3().render()),
            "fig4b" => println!("{}", figures::fig4b().render()),
            "fig6" => println!("{}", figures::fig6().render()),
            "fig9" => println!("{}", figures::fig9()?.0.render()),
            "fig10" | "fig11" => {
                println!("-- block-size sweep (Figs. 10a/11a) --\n{}", figures::fig10_11_block()?.render());
                println!("-- precision sweep (Figs. 10b/11b) --\n{}", figures::fig10_11_precision()?.render());
            }
            "fig13" => println!("{}", figures::fig13()?.render()),
            "fig14" => println!("{}", figures::fig14()?.render()),
            "fig15" => println!("{}", figures::fig15()?.render()),
            "headline" => println!("{}", figures::headline_claims()?.render()),
            other => bail!("unknown figure {other}"),
        }
        Ok(())
    };
    if which == "all" {
        for id in ["fig3", "fig4b", "fig6", "fig9", "fig10", "fig13", "fig14", "fig15", "headline"] {
            show(id)?;
        }
        Ok(())
    } else {
        show(which)
    }
}

fn artifact_opts() -> Vec<Opt> {
    vec![
        Opt { name: "artifacts", default: Some("artifacts"), help: "artifact directory (make artifacts)" },
        Opt { name: "pes", default: Some("10"), help: "number of PEs" },
        Opt { name: "emit-asm", default: None, help: "print the compiled instruction stream" },
        Opt { name: "n", default: Some("32"), help: "number of test vectors" },
    ]
}

fn load_program(dir: &str, n_pes: usize) -> Result<apu::isa::Program> {
    let model = import_bundle(&format!("{dir}/lenet_model.json"))
        .context("importing model bundle — run `make artifacts` first")?;
    compile_packed_layers(&model.name, &model.layers, model.in_scale, model.bits, n_pes)
}

fn cmd_compile(argv: &[String]) -> Result<()> {
    let opts = vec![
        Opt {
            name: "net",
            default: Some("artifact"),
            help: "artifact | lenet | alexnet[-nano] | vgg19[-dense] | resnet50[-dense] | vgg-nano | mha",
        },
        Opt {
            name: "machine",
            default: Some("paper"),
            help: "mapping target (zoo networks): paper (9×513×513) | nano (4×64×128)",
        },
        Opt { name: "seed", default: Some("7"), help: "synthetic weight seed (zoo networks)" },
        Opt { name: "out", default: Some(""), help: "write the program artifact to this path" },
        Opt { name: "artifacts", default: Some("artifacts"), help: "artifact directory (--net artifact)" },
        Opt {
            name: "pes",
            default: Some("auto"),
            help: "PE count override (auto = 10 for artifact, the machine's default for zoo)",
        },
        Opt { name: "emit-asm", default: None, help: "print the compiled instruction stream" },
    ];
    let args = parse(argv, &opts)?;
    if args.has_flag("help") {
        println!("{}", usage("compile", "Compile a network to an APU program", &opts));
        return Ok(());
    }
    let out = args.req("out")?.to_string();
    let net_name = args.req("net")?.to_string();
    let pes_arg = args.req("pes")?.to_string();
    let pes_override = if pes_arg == "auto" {
        None
    } else {
        Some(pes_arg.parse::<usize>().context("--pes must be a number or 'auto'")?)
    };

    if net_name == "artifact" {
        // The python-trained LeNet bundle: packed FC stack → program.
        let program = load_program(args.req("artifacts")?, pes_override.unwrap_or(10))?;
        println!(
            "compiled {}: {} instructions, {} data segments, din={} dout={}",
            program.name,
            program.insns.len(),
            program.data.len(),
            program.din,
            program.dout
        );
        if args.has_flag("emit-asm") {
            println!("{}", program.disassemble());
        }
        if !out.is_empty() {
            program.save(&out)?;
            println!("wrote program artifact to {out}");
        }
        return Ok(());
    }

    // Zoo network through the pass-based pipeline.
    let net = apu::nn::zoo::by_name(&net_name).with_context(|| {
        format!("unknown zoo network {net_name} (available: {})", apu::nn::zoo::names().join(", "))
    })?;
    let mut model = match args.req("machine")? {
        "paper" => CostModel::paper_9pe(),
        "nano" => CostModel::nano_4pe(),
        other => bail!("unknown --machine {other} (want paper | nano)"),
    };
    if let Some(pes) = pes_override {
        model.n_pes = pes;
    }
    println!(
        "{} mapped onto {} PEs of {}×{} @ INT{}:",
        net.name, model.n_pes, model.pe_h, model.pe_w, model.bits
    );
    let popts = PipelineOptions { seed: args.get_usize("seed")? as u64, ..Default::default() };
    match pipeline::compile_network(&net, &model, &popts) {
        Ok(compiled) => {
            print!("{}", compiled.table());
            println!(
                "emitted {}: {} instructions, {} data segments, din={} dout={}",
                compiled.program.name,
                compiled.program.insns.len(),
                compiled.program.data.len(),
                compiled.program.din,
                compiled.program.dout
            );
            if args.has_flag("emit-asm") {
                println!("{}", compiled.program.disassemble());
            }
            if !out.is_empty() {
                compiled.program.save(&out)?;
                println!("wrote program artifact to {out}");
            }
        }
        Err(e) => {
            // Emission refused (case II / attention / budget): still print
            // the analytic mapping table, which covers every layer kind.
            print!("{}", pipeline::analyze(&net, &model)?.table());
            if !out.is_empty() {
                return Err(e.context("emission failed but --out was requested"));
            }
            println!("(analytic only — not emitted: {e:#})");
        }
    }
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    let args = parse(argv, &artifact_opts())?;
    let dir = args.req("artifacts")?.to_string();
    let n_pes = args.get_usize("pes")?;
    let program = load_program(&dir, n_pes)?;
    let mut apu = Apu::new(ApuConfig { n_pes, ..Default::default() });
    apu.load(&program)?;

    let tv = Bundle::load(format!("{dir}/testvec.json"))?;
    let x = tv.tensor("x")?.as_f32()?;
    let y = tv.tensor("y")?.as_i32()?;
    let golden = tv.tensor("logits")?.as_f32()?;
    let din = tv.shape("x")?[1];
    let dout = tv.shape("logits")?[1];
    let n = args.get_usize("n")?.min(tv.shape("x")?[0]);

    let mut correct = 0;
    let mut agree = 0;
    let mut maxdiff = 0f32;
    for i in 0..n {
        let out = apu.run(&x[i * din..(i + 1) * din])?;
        let pred = argmax(&out);
        let gold = &golden[i * dout..(i + 1) * dout];
        if pred == argmax(gold) {
            agree += 1;
        }
        if pred == y[i] as usize {
            correct += 1;
        }
        for (a, b) in out.iter().zip(gold) {
            maxdiff = maxdiff.max((a - b).abs());
        }
    }
    let st = apu.stats();
    println!("simulated {n} inferences on {n_pes} PEs:");
    println!("  accuracy          {:.3}", correct as f64 / n as f64);
    println!("  golden agreement  {agree}/{n} (max |logit diff| {maxdiff:.2e})");
    println!(
        "  cycles/inference  {} (route {}, compute {}, host {})",
        st.total_cycles() / n as u64,
        st.route_cycles / n as u64,
        st.compute_cycles / n as u64,
        st.host_cycles / n as u64
    );
    println!(
        "  energy/inference  {:.1} nJ  |  effective {:.2} GOPS @1GHz, {:.1} TOPS/W (datapath)",
        st.total_pj() / n as f64 / 1000.0,
        st.effective_gops(1.0),
        st.tops_per_watt()
    );
    Ok(())
}

fn cmd_profile(argv: &[String]) -> Result<()> {
    let opts = vec![
        Opt { name: "net", default: Some("vgg-nano"), help: "zoo network (e.g. vgg-nano, alexnet-nano)" },
        Opt { name: "machine", default: Some("nano"), help: "mapping target: paper (9×513×513) | nano (4×64×128)" },
        Opt { name: "seed", default: Some("7"), help: "synthetic weight seed" },
        Opt { name: "runs", default: Some("2"), help: "inferences to profile" },
        Opt { name: "threads", default: Some("1"), help: "lane-pool workers for the batched run (bitwise invisible)" },
        Opt { name: "trace-out", default: Some(""), help: "write a Chrome trace-event JSON (compiler passes + sim phases)" },
    ];
    let args = parse(argv, &opts)?;
    if args.has_flag("help") {
        println!("{}", usage("profile", "Per-layer cycle/energy breakdown of a zoo network", &opts));
        return Ok(());
    }
    let net_name = args.req("net")?.to_string();
    let net = apu::nn::zoo::by_name(&net_name).with_context(|| {
        format!("unknown zoo network {net_name} (available: {})", apu::nn::zoo::names().join(", "))
    })?;
    let model = match args.req("machine")? {
        "paper" => CostModel::paper_9pe(),
        "nano" => CostModel::nano_4pe(),
        other => bail!("unknown --machine {other} (want paper | nano)"),
    };
    let runs = args.get_usize("runs")?.max(1);
    let threads = args.get_usize("threads")?.max(1);
    let trace_out = args.req("trace-out")?.to_string();

    let tracer = Tracer::new();
    let popts = PipelineOptions {
        seed: args.get_usize("seed")? as u64,
        tracer: Some(tracer.clone()),
        ..Default::default()
    };
    let compiled = pipeline::compile_network(&net, &model, &popts)?;
    let cfg = model.apu_config();
    let clock_ghz = cfg.clock_ghz;
    let mut sim = Apu::new(cfg);
    sim.load(&compiled.program)?;
    sim.enable_profiling();
    sim.set_threads(threads);
    let mut rng = Rng::new(popts.seed ^ 0xda7a);
    // One batched run over all inputs: the lane pool splits the lanes
    // across `threads` workers, and the profile==stats check below
    // exercises the bitwise-exactness invariant under threading.
    let inputs: Vec<Vec<f32>> = (0..runs)
        .map(|_| (0..compiled.program.din).map(|_| rng.uniform(-1.0, 1.0)).collect())
        .collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    sim.run_batch(&refs)?;
    let st = sim.stats().clone();
    let profile = sim.take_profile().context("profiling was enabled but no profile recorded")?;
    // The profiler's invariant, enforced rather than assumed: its
    // per-phase records sum to exactly the figures SimStats reports.
    profile.check_against(&st)?;

    let names: Vec<String> = compiled.cost.layers.iter().map(|l| l.name.clone()).collect();
    println!(
        "{} on {} PEs of {}×{} @ INT{} — {runs} inference(s), profile == SimStats (checked):",
        net.name, model.n_pes, model.pe_h, model.pe_w, model.bits
    );
    print!("{}", profile.table(&names));
    println!(
        "effective {:.2} GOPS @{:.1}GHz, {:.2} TOPS/W (datapath)",
        st.effective_gops(clock_ghz),
        clock_ghz,
        st.tops_per_watt()
    );
    if !trace_out.is_empty() {
        // One file, two lanes: compiler passes (wall clock) and the
        // simulator's cycle timeline mapped through the clock.
        tracer.extend(profile.trace_events(clock_ghz));
        tracer.write_chrome_trace(&trace_out)?;
        println!("wrote Chrome trace to {trace_out} (open via chrome://tracing or Perfetto)");
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let opts = vec![
        Opt { name: "engine", default: Some("sim"), help: "sim | golden" },
        Opt { name: "requests", default: Some("64"), help: "request count" },
        Opt { name: "rate", default: Some("200"), help: "arrival rate, req/s" },
        Opt { name: "batch", default: Some("8"), help: "max batch size" },
        Opt { name: "artifacts", default: Some("artifacts"), help: "artifact directory" },
        Opt { name: "pes", default: Some("10"), help: "number of PEs (sim engine)" },
    ];
    let args = parse(argv, &opts)?;
    let engine_kind = args.req("engine")?.to_string();
    let n = args.get_usize("requests")?;
    let rate = args.get_f64("rate")?;
    let batch = args.get_usize("batch")?;
    let dir = args.req("artifacts")?.to_string();
    let n_pes = args.get_usize("pes")?;

    let policy = BatchPolicy { max_batch: batch, max_wait: std::time::Duration::from_millis(2) };
    let dir2 = dir.clone();
    let server = match engine_kind.as_str() {
        "sim" => Server::start(
            move || {
                let model = import_bundle(&format!("{dir2}/lenet_model.json"))?;
                let program = compile_packed_layers(&model.name, &model.layers, model.in_scale, model.bits, n_pes)?;
                let apu = Apu::new(ApuConfig { n_pes, ..Default::default() });
                Ok(Box::new(ApuEngine::new(apu, &program)?) as Box<dyn apu::coordinator::Engine>)
            },
            policy,
        )?,
        "golden" => Server::start(
            move || {
                let manifest = Manifest::load(&dir2)?;
                Ok(Box::new(GoldenEngine::from_artifacts(&manifest, 800, 10)?) as Box<dyn apu::coordinator::Engine>)
            },
            policy,
        )?,
        other => bail!("unknown engine {other}"),
    };

    let mut load = SyntheticLoad::new(rate, 42);
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(n);
    for _ in 0..n {
        std::thread::sleep(load.next_gap());
        receivers.push(server.submit(load.next_input(800))?);
    }
    for rx in receivers {
        rx.recv()?;
    }
    let elapsed = t0.elapsed();
    let mut metrics = server.shutdown()?;
    println!("engine={engine_kind} served {} requests in {:.2}s", metrics.completed, elapsed.as_secs_f64());
    println!("  throughput  {:.1} req/s", metrics.throughput_rps(elapsed));
    println!(
        "  latency     p50 {:.0} us | p99 {:.0} us | mean {:.0} us",
        metrics.latency_us.median(),
        metrics.latency_us.p99(),
        metrics.latency_us.mean()
    );
    println!("  batches     {} (mean size {:.2})", metrics.batches, metrics.batch_sizes.mean());
    println!("  engine time mean {:.0} us/batch", metrics.engine_us.mean());
    Ok(())
}

fn cmd_fleet(argv: &[String]) -> Result<()> {
    let opts = vec![
        Opt { name: "shards", default: Some("4"), help: "shard workers (per model when --models is given)" },
        Opt { name: "policy", default: Some("jsq"), help: "dispatch: rr | lo | jsq" },
        Opt { name: "requests", default: Some("256"), help: "request count" },
        Opt { name: "rate", default: Some("2000"), help: "arrival rate, req/s" },
        Opt { name: "batch", default: Some("8"), help: "max batch size per shard" },
        Opt { name: "queue-cap", default: Some("64"), help: "per-shard queue bound (admission control)" },
        Opt { name: "model", default: Some("synthetic"), help: "synthetic | artifact | zoo:<name> (e.g. zoo:vgg-nano, zoo:alexnet-nano)" },
        Opt {
            name: "models",
            default: Some(""),
            help: "multi-model fleet: comma-separated specs (zoo:<name> or .apu path); overrides --model",
        },
        Opt {
            name: "mix",
            default: Some(""),
            help: "traffic weights matching --models, e.g. 70,20,10 (default uniform)",
        },
        Opt { name: "pes", default: Some("4"), help: "PEs per shard engine" },
        Opt { name: "threads", default: Some("1"), help: "lane-pool workers per shard engine (bitwise invisible)" },
        Opt {
            name: "cache",
            default: Some("1024"),
            help: "result-cache entries per model (catalog fleets only; 0 disables)",
        },
        Opt { name: "no-cache", default: None, help: "disable the result cache (same as --cache 0)" },
        Opt { name: "artifacts", default: Some("artifacts"), help: "artifact directory (--model artifact)" },
        Opt {
            name: "metrics-out",
            default: Some(""),
            help: "dump the metrics registry at shutdown (.json = JSON, else Prometheus text)",
        },
        Opt { name: "trace-out", default: Some(""), help: "write per-request spans as Chrome trace-event JSON" },
    ];
    let args = parse(argv, &opts)?;
    if args.has_flag("help") {
        println!("{}", usage("fleet", "Run the sharded multi-engine serving fleet", &opts));
        return Ok(());
    }
    let shards = args.get_usize("shards")?;
    let policy_arg = args.req("policy")?;
    let policy = DispatchPolicy::parse(policy_arg).with_context(|| {
        let valid: Vec<&str> = DispatchPolicy::ALL.iter().map(|p| p.name()).collect();
        format!("unknown --policy {policy_arg} (valid: rr | lo | jsq, long forms: {})", valid.join(" | "))
    })?;
    let n = args.get_usize("requests")?;
    let rate = args.get_f64("rate")?;
    let metrics_out = args.req("metrics-out")?.to_string();
    let trace_out = args.req("trace-out")?.to_string();
    let registry = metrics::global();
    let tracer = (!trace_out.is_empty()).then(Tracer::new);
    let threads = args.get_usize("threads")?.max(1);
    let cache_entries = if args.has_flag("no-cache") { 0 } else { args.get_usize("cache")? };
    let config = FleetConfig {
        shards,
        policy,
        batch: BatchPolicy {
            max_batch: args.get_usize("batch")?,
            max_wait: std::time::Duration::from_millis(2),
        },
        queue_cap: args.get_usize("queue-cap")?,
        metrics: registry.clone(),
        tracer: tracer.clone(),
        threads_per_shard: threads,
        cache_entries,
    };
    let n_pes = args.get_usize("pes")?;

    // Multi-model fleet: resolve every spec into a shared-plan catalog,
    // build one shard group per model, and drive a weighted traffic mix.
    let models_arg = args.req("models")?.to_string();
    if !models_arg.is_empty() {
        let specs: Vec<&str> =
            models_arg.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
        let catalog = std::sync::Arc::new(ModelCatalog::from_specs(&specs, Some(n_pes))?);
        let mix_arg = args.req("mix")?.to_string();
        let weights: Vec<f32> = if mix_arg.is_empty() {
            vec![1.0; catalog.len()]
        } else {
            let w = mix_arg
                .split(',')
                .map(|s| s.trim().parse::<f32>().with_context(|| format!("bad --mix weight {s:?}")))
                .collect::<Result<Vec<f32>>>()?;
            if w.len() != catalog.len() {
                bail!("--mix has {} weights for {} models", w.len(), catalog.len());
            }
            if w.iter().any(|&x| x < 0.0) || w.iter().sum::<f32>() <= 0.0 {
                bail!("--mix weights must be non-negative with a positive sum");
            }
            w
        };
        let dins: Vec<usize> = catalog.iter().map(|(_, e)| e.program.din).collect();
        let per_model = vec![shards; catalog.len()];
        let fleet = Fleet::start_catalog(config, std::sync::Arc::clone(&catalog), &per_model)?;
        let cache = apu::sim::plan_cache_stats();
        println!(
            "serving {} model(s) × {shards} shard(s) each — plan cache: {} build(s), {} hit(s)",
            catalog.len(),
            cache.builds,
            cache.hits
        );
        // With the result cache on, draw each model's inputs from a small
        // Zipf-skewed pool so repeats actually occur (uniform random f32
        // vectors would never collide and the cache would sit cold).
        let pools: Option<Vec<InputPool>> = (cache_entries > 0).then(|| {
            dins.iter()
                .enumerate()
                .map(|(i, &d)| InputPool::zipf(d, 64, 1.1, 4242 + i as u64))
                .collect()
        });
        if pools.is_some() {
            println!("result cache: {cache_entries} entries/model, Zipf(1.1) input pool of 64");
        }
        let total: f32 = weights.iter().sum();
        let mut load = SyntheticLoad::new(rate, 42);
        let t0 = std::time::Instant::now();
        let mut receivers = Vec::with_capacity(n);
        let mut rejected_at_submit = 0u64;
        for _ in 0..n {
            std::thread::sleep(load.next_gap());
            // sample the target model from the mix weights
            let mut pick = load.rng.uniform(0.0, total);
            let mut m = weights.len() - 1;
            for (i, w) in weights.iter().enumerate() {
                if pick < *w {
                    m = i;
                    break;
                }
                pick -= w;
            }
            let input = match &pools {
                Some(p) => p[m].sample(&mut load.rng),
                None => load.next_input(dins[m]),
            };
            match fleet.submit_to(ModelId(m), input) {
                Ok(rx) => receivers.push(rx),
                Err(SubmitError::Rejected { .. }) => rejected_at_submit += 1,
                Err(e) => return Err(e.into()),
            }
        }
        return finish_fleet_run(
            fleet,
            receivers,
            rejected_at_submit,
            n,
            t0,
            &registry,
            &metrics_out,
            &trace_out,
            tracer,
        );
    }

    let (din, fleet) = match args.req("model")? {
        "synthetic" => {
            // Self-contained: a synthetic packed network per shard, no
            // `make artifacts` needed.
            let fleet = Fleet::start(config, move |shard| {
                let layers = synthetic_packed_network(&[64, 48, 10], n_pes, 4, 1000 + shard as u64)?;
                let program = compile_packed_layers("fleet", &layers, 0.15, 4, n_pes)?;
                let apu = Apu::new(ApuConfig { n_pes, pe_sram_bits: 1 << 20, clock_ghz: 1.0 });
                let mut engine = ApuEngine::new(apu, &program)?;
                engine.set_threads(threads);
                Ok(Box::new(engine) as Box<dyn apu::coordinator::Engine>)
            })?;
            (64, fleet)
        }
        "artifact" => {
            let dir = args.req("artifacts")?.to_string();
            let fleet = Fleet::start(config, move |_| {
                let model = import_bundle(&format!("{dir}/lenet_model.json"))?;
                let program =
                    compile_packed_layers(&model.name, &model.layers, model.in_scale, model.bits, n_pes)?;
                let apu = Apu::new(ApuConfig { n_pes, ..Default::default() });
                let mut engine = ApuEngine::new(apu, &program)?;
                engine.set_threads(threads);
                Ok(Box::new(engine) as Box<dyn apu::coordinator::Engine>)
            })?;
            (800, fleet)
        }
        m if m.starts_with("zoo:") => {
            // A zoo network compiled once through the pipeline; every
            // shard serves the same program on its own simulator.
            let name = m.strip_prefix("zoo:").unwrap();
            let net = apu::nn::zoo::by_name(name).with_context(|| {
                format!("unknown zoo network {name} (available: {})", apu::nn::zoo::names().join(", "))
            })?;
            // The -nano networks map onto the nano instance (vgg-nano
            // untiled, alexnet-nano exercising the §4.4.3-II folds);
            // everything else gets the paper geometry (513-wide PEs).
            // (Compare the canonical zoo name, not the CLI spelling.)
            let mut machine = if net.name.ends_with("-nano") {
                CostModel::nano_4pe()
            } else {
                CostModel::paper_9pe()
            };
            machine.n_pes = n_pes;
            let compiled = pipeline::compile_network(&net, &machine, &PipelineOptions::default())
                .with_context(|| format!("compiling {name} for the fleet"))?;
            let din = compiled.program.din;
            let fleet = Fleet::start(config, move |_| {
                let mut engine = ApuEngine::from_compiled(&compiled)?;
                engine.set_threads(threads);
                Ok(Box::new(engine) as Box<dyn apu::coordinator::Engine>)
            })?;
            (din, fleet)
        }
        other => bail!(
            "unknown --model {other} (valid: synthetic | artifact | zoo:<name>; zoo networks: {})",
            apu::nn::zoo::names().join(", ")
        ),
    };

    let mut load = SyntheticLoad::new(rate, 42);
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(n);
    let mut rejected_at_submit = 0u64;
    for _ in 0..n {
        std::thread::sleep(load.next_gap());
        match fleet.submit(load.next_input(din)) {
            Ok(rx) => receivers.push(rx),
            Err(SubmitError::Rejected { .. }) => rejected_at_submit += 1,
            Err(e) => return Err(e.into()),
        }
    }
    finish_fleet_run(
        fleet,
        receivers,
        rejected_at_submit,
        n,
        t0,
        &registry,
        &metrics_out,
        &trace_out,
        tracer,
    )
}

/// Shared tail of `apu fleet`: wait for every reply, shut the fleet
/// down, print the SLO report, and honor `--metrics-out`/`--trace-out`.
#[allow(clippy::too_many_arguments)]
fn finish_fleet_run(
    fleet: Fleet,
    receivers: Vec<std::sync::mpsc::Receiver<Reply>>,
    rejected_at_submit: u64,
    n: usize,
    t0: std::time::Instant,
    registry: &std::sync::Arc<metrics::Registry>,
    metrics_out: &str,
    trace_out: &str,
    tracer: Option<Tracer>,
) -> Result<()> {
    for rx in receivers {
        rx.recv()?;
    }
    let elapsed = t0.elapsed();
    let fleet_metrics = fleet.shutdown()?;
    let report = SloReport::from_metrics(&fleet_metrics, elapsed);
    println!("{}", report.render());
    if rejected_at_submit > 0 {
        println!("({rejected_at_submit} of {n} arrivals rejected by admission control)");
    }
    if !metrics_out.is_empty() {
        // Fold the end-of-run SLO gauges and the plan-cache snapshot into
        // the same dump as the live shard counters, then export in the
        // format the path implies.
        report.export(registry);
        apu::sim::export_plan_cache_metrics(registry);
        let body = if metrics_out.ends_with(".json") {
            registry.to_json().pretty()
        } else {
            registry.render_prometheus()
        };
        std::fs::write(metrics_out, body)
            .with_context(|| format!("writing metrics to {metrics_out}"))?;
        println!("wrote metrics to {metrics_out}");
    }
    if let Some(t) = tracer {
        t.write_chrome_trace(trace_out)
            .with_context(|| format!("writing trace to {trace_out}"))?;
        println!("wrote Chrome trace to {trace_out} ({} spans)", t.len());
    }
    Ok(())
}

fn cmd_dse(argv: &[String]) -> Result<()> {
    let opts = vec![Opt { name: "sweep", default: Some("block"), help: "block | precision" }];
    let args = parse(argv, &opts)?;
    match args.req("sweep")? {
        "block" => println!("{}", figures::fig10_11_block()?.render()),
        "precision" => println!("{}", figures::fig10_11_precision()?.render()),
        other => bail!("unknown sweep {other}"),
    }
    Ok(())
}

fn cmd_netlist(argv: &[String]) -> Result<()> {
    let opts = vec![
        Opt { name: "pes", default: Some("10"), help: "number of PEs" },
        Opt { name: "block", default: Some("400"), help: "block dim (square)" },
        Opt { name: "bits", default: Some("4"), help: "precision" },
    ];
    let args = parse(argv, &opts)?;
    let cfg = GeneratorConfig {
        n_pes: args.get_usize("pes")?,
        block_h: args.get_usize("block")?,
        block_w: args.get_usize("block")?,
        bits: args.get_usize("bits")? as u32,
        ..Default::default()
    };
    let inst = DesignInstance::generate(cfg)?;
    println!("{}", inst.netlist());
    println!("{}", inst.spec_json().pretty());
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}
