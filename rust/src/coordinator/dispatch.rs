//! Pluggable request dispatch for the serving fleet.
//!
//! The dispatcher is deliberately decoupled from the shard workers: it
//! sees only a per-shard [`ShardLoad`] snapshot (queued depth, in-flight
//! count, liveness) and returns the index of the shard a request should
//! join. That keeps every policy a pure function over the snapshot —
//! trivially unit-testable without spinning up engines — while the
//! [`Fleet`](super::fleet::Fleet) keeps the snapshots fresh via atomics.
//!
//! Policies (SoftNeuro-style routing choices; see ROADMAP "Fleet serving"):
//! * `RoundRobin` — cyclic, load-blind; the baseline.
//! * `LeastOutstanding` — fewest in-flight requests (queued + executing);
//!   tracks actual shard busyness, the classic least-connections policy.
//! * `JoinShortestQueue` — fewest requests still waiting to be batched;
//!   ignores the batch currently executing, so it reacts faster to a
//!   shard that has just drained its queue into the engine.

use std::sync::atomic::{AtomicUsize, Ordering};

/// One shard's load as seen by the dispatcher at selection time.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardLoad {
    /// Requests admitted but not yet taken into an executing batch.
    pub queued: usize,
    /// Requests admitted but not yet replied to (queued + executing).
    pub outstanding: usize,
    /// False once the shard's engine factory failed or its worker exited.
    pub alive: bool,
}

/// Dispatch policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    RoundRobin,
    LeastOutstanding,
    JoinShortestQueue,
}

impl DispatchPolicy {
    /// Parse a CLI spelling (`rr | lo | jsq` or the long names).
    pub fn parse(s: &str) -> Option<DispatchPolicy> {
        match s {
            "rr" | "round-robin" | "roundrobin" => Some(DispatchPolicy::RoundRobin),
            "lo" | "least-outstanding" | "leastoutstanding" => Some(DispatchPolicy::LeastOutstanding),
            "jsq" | "join-shortest-queue" | "joinshortestqueue" => Some(DispatchPolicy::JoinShortestQueue),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::LeastOutstanding => "least-outstanding",
            DispatchPolicy::JoinShortestQueue => "join-shortest-queue",
        }
    }

    pub const ALL: [DispatchPolicy; 3] =
        [DispatchPolicy::RoundRobin, DispatchPolicy::LeastOutstanding, DispatchPolicy::JoinShortestQueue];
}

/// Stateful dispatcher: the policy plus the round-robin cursor. `select`
/// takes `&self` so concurrent submitters need no lock.
#[derive(Debug)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    cursor: AtomicUsize,
}

impl Dispatcher {
    pub fn new(policy: DispatchPolicy) -> Dispatcher {
        Dispatcher { policy, cursor: AtomicUsize::new(0) }
    }

    pub fn policy(&self) -> DispatchPolicy {
        self.policy
    }

    /// Pick the shard a new request should join, or `None` when no shard
    /// is alive. Load-aware policies break ties by lowest index, so
    /// selection is deterministic for a given snapshot.
    pub fn select(&self, loads: &[ShardLoad]) -> Option<usize> {
        if !loads.iter().any(|l| l.alive) {
            return None;
        }
        match self.policy {
            DispatchPolicy::RoundRobin => {
                // Cycle over the *live* shards only, so a dead shard's
                // traffic spreads evenly instead of doubling up on its
                // successor; the fetch_add makes concurrent submitters
                // interleave instead of colliding.
                let alive: Vec<usize> =
                    loads.iter().enumerate().filter(|(_, l)| l.alive).map(|(i, _)| i).collect();
                let k = self.cursor.fetch_add(1, Ordering::Relaxed) % alive.len();
                Some(alive[k])
            }
            DispatchPolicy::LeastOutstanding => {
                argmin_alive(loads, |l| l.outstanding)
            }
            DispatchPolicy::JoinShortestQueue => {
                argmin_alive(loads, |l| l.queued)
            }
        }
    }
}

fn argmin_alive(loads: &[ShardLoad], key: impl Fn(&ShardLoad) -> usize) -> Option<usize> {
    loads
        .iter()
        .enumerate()
        .filter(|(_, l)| l.alive)
        .min_by_key(|(i, l)| (key(l), *i))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle(n: usize) -> Vec<ShardLoad> {
        vec![ShardLoad { queued: 0, outstanding: 0, alive: true }; n]
    }

    #[test]
    fn round_robin_distributes_evenly() {
        let d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let loads = idle(4);
        let mut counts = [0usize; 4];
        for _ in 0..400 {
            counts[d.select(&loads).unwrap()] += 1;
        }
        assert_eq!(counts, [100, 100, 100, 100]);
    }

    #[test]
    fn round_robin_skips_dead_shards() {
        let d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let mut loads = idle(4);
        loads[1].alive = false;
        let mut counts = [0usize; 4];
        for _ in 0..300 {
            counts[d.select(&loads).unwrap()] += 1;
        }
        assert_eq!(counts[1], 0);
        assert_eq!(counts.iter().sum::<usize>(), 300);
        // remaining shards still share the load evenly
        assert_eq!(counts[0], 100);
        assert_eq!(counts[2], 100);
        assert_eq!(counts[3], 100);
    }

    #[test]
    fn least_outstanding_prefers_idle_shard_under_skew() {
        let d = Dispatcher::new(DispatchPolicy::LeastOutstanding);
        let loads = vec![
            ShardLoad { queued: 0, outstanding: 9, alive: true },
            ShardLoad { queued: 0, outstanding: 3, alive: true },
            ShardLoad { queued: 0, outstanding: 0, alive: true }, // idle
            ShardLoad { queued: 0, outstanding: 7, alive: true },
        ];
        for _ in 0..10 {
            assert_eq!(d.select(&loads), Some(2));
        }
    }

    #[test]
    fn join_shortest_queue_prefers_short_queue_not_low_outstanding() {
        // First snapshot: shard 1 is better on both signals, so JSQ and
        // LeastOutstanding agree on it. The second snapshot splits them:
        // shard 1 has the shorter queue but more in flight, so JSQ keeps
        // picking 1 while LeastOutstanding switches to 0.
        let loads = vec![
            ShardLoad { queued: 8, outstanding: 8, alive: true },
            ShardLoad { queued: 0, outstanding: 4, alive: true },
        ];
        assert_eq!(Dispatcher::new(DispatchPolicy::JoinShortestQueue).select(&loads), Some(1));
        assert_eq!(
            Dispatcher::new(DispatchPolicy::LeastOutstanding).select(&loads),
            Some(1),
        );
        let loads2 = vec![
            ShardLoad { queued: 8, outstanding: 8, alive: true },
            ShardLoad { queued: 2, outstanding: 12, alive: true },
        ];
        assert_eq!(Dispatcher::new(DispatchPolicy::JoinShortestQueue).select(&loads2), Some(1));
        assert_eq!(Dispatcher::new(DispatchPolicy::LeastOutstanding).select(&loads2), Some(0));
    }

    #[test]
    fn load_aware_ties_break_deterministically() {
        let d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
        let loads = idle(3);
        for _ in 0..5 {
            assert_eq!(d.select(&loads), Some(0));
        }
    }

    #[test]
    fn all_dead_yields_none() {
        for p in DispatchPolicy::ALL {
            let d = Dispatcher::new(p);
            let mut loads = idle(2);
            loads[0].alive = false;
            loads[1].alive = false;
            assert_eq!(d.select(&loads), None);
        }
    }

    #[test]
    fn policy_parse_round_trips() {
        for p in DispatchPolicy::ALL {
            assert_eq!(DispatchPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(DispatchPolicy::parse("rr"), Some(DispatchPolicy::RoundRobin));
        assert_eq!(DispatchPolicy::parse("lo"), Some(DispatchPolicy::LeastOutstanding));
        assert_eq!(DispatchPolicy::parse("jsq"), Some(DispatchPolicy::JoinShortestQueue));
        assert_eq!(DispatchPolicy::parse("nope"), None);
    }
}
