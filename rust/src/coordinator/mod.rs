//! Edge-inference serving coordinator — the L3 request path.
//!
//! The paper's deployment story (§1, §6) is an edge SoC serving inference
//! under real-time constraints. This module is the framework around the
//! accelerator: a request queue, a deadline-aware dynamic batcher, shard
//! workers driving inference engines (the cycle-accurate APU simulator or
//! the PJRT golden model — python is never on this path), and
//! latency/throughput metrics.
//!
//! Scaling out happens in [`fleet`]: N shard workers (each with its own
//! engine + batcher) behind a pluggable [`dispatch`] policy, with bounded
//! per-shard queues (admission control) and [`slo`] reporting
//! (p50/p95/p99, queue depth, rejection rate). The single-engine
//! [`Server`] is the 1-shard special case of the fleet.
//!
//! Serving is model-keyed: a [`catalog::ModelCatalog`] resolves named
//! models (zoo specs or `.apu` artifacts) into shared programs and
//! execution plans, [`Fleet::start_catalog`] spawns one shard group per
//! model, requests carry a [`ModelId`], and SLO/metrics output is
//! labelled per model as well as per shard.
//!
//! A request-level result [`cache`] can sit in front of the whole
//! dispatch path: catalog-backed fleets key each request on (program
//! fingerprint, machine key, canonical quantized input) and serve
//! repeats verbatim — sound because planned runs are input-
//! deterministic. Hits reply *before* admission control, so the queue
//! signal and every per-shard metric see only real engine traffic; the
//! cache keeps its own `apu_fleet_cache_*` series and SLO table.
//!
//! Every shard also registers per-shard counters/gauges/histograms in a
//! [`crate::obs::metrics::Registry`] (the process-global one by default;
//! inject a private registry through [`FleetConfig::metrics`] for tests),
//! and — when [`FleetConfig::tracer`] is set — records per-request spans
//! (enqueue → dequeue → batch assembly → engine run → reply) plus one
//! "engine-run" span per batch into a [`crate::obs::trace::Tracer`] for
//! Chrome trace-event export.

pub mod batcher;
pub mod cache;
pub mod catalog;
pub mod dispatch;
pub mod engine;
pub mod fleet;
pub mod server;
pub mod slo;

pub use batcher::{BatchPolicy, Batcher, FlushReason};
pub use cache::{CacheKey, CacheStats, InputKeyer, ResultCache};
pub use catalog::{ModelCatalog, ModelEntry, ModelId};
pub use dispatch::{DispatchPolicy, Dispatcher, ShardLoad};
pub use engine::{ApuEngine, Engine, GoldenEngine};
pub use fleet::{Fleet, FleetConfig, FleetMetrics, Group, SubmitError, CACHE_SHARD};
pub use server::{InputPool, Reply, ServeError, Server, ServerMetrics, SyntheticLoad};
pub use slo::{SloReport, SloSnapshot};
