//! Edge-inference serving coordinator — the L3 request path.
//!
//! The paper's deployment story (§1, §6) is an edge SoC serving inference
//! under real-time constraints. This module is the framework around the
//! accelerator: a request queue, a deadline-aware dynamic batcher, a
//! worker thread driving an inference engine (the cycle-accurate APU
//! simulator or the PJRT golden model — python is never on this path),
//! and latency/throughput metrics.

pub mod batcher;
pub mod engine;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{ApuEngine, Engine, GoldenEngine};
pub use server::{Server, ServerMetrics, SyntheticLoad};
