//! The single-engine serving loop — the 1-shard special case of the
//! [`Fleet`](super::fleet::Fleet).
//!
//! `Server` keeps the original one-engine API (FnOnce factory, unbounded
//! queue, `ServerMetrics` on shutdown) but runs on the fleet's shared
//! shard-worker code path (`fleet::serve_loop`), so batching, error
//! replies, and metrics behave identically whether one engine or eight
//! are serving.

use std::sync::{mpsc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::batcher::BatchPolicy;
use super::dispatch::DispatchPolicy;
use super::engine::Engine;
use super::fleet::{Fleet, FleetConfig};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// How a served request can fail after admission.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The engine failed on the batch this request rode in.
    Engine(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Engine(msg) => write!(f, "engine error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// The response handed back to the caller.
#[derive(Debug)]
pub struct Reply {
    /// The inference result, or the explicit per-request error when the
    /// engine failed on this batch (the batch is never silently dropped).
    pub output: Result<Vec<f32>, ServeError>,
    pub latency: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// The shard that served the request (0 for a single-engine server).
    pub shard: usize,
    /// The model the request targeted (`ModelId(0)` for single-model
    /// fleets and the single-engine server).
    pub model: super::catalog::ModelId,
    /// True when the reply came from the fleet's result cache. Cached
    /// replies never touched a shard queue, batcher, or engine:
    /// `batch_size` is 0 and `shard` is
    /// [`CACHE_SHARD`](super::fleet::CACHE_SHARD).
    pub cached: bool,
}

impl Reply {
    /// The output, with an engine failure converted into an `anyhow`
    /// error (convenience for callers that just propagate).
    pub fn into_output(self) -> Result<Vec<f32>> {
        self.output.map_err(anyhow::Error::from)
    }
}

/// Aggregated serving metrics for one engine (one fleet shard).
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub completed: u64,
    /// Requests that got an explicit engine-error reply.
    pub failed: u64,
    /// Requests refused by admission control (always 0 for the unbounded
    /// single-engine server; filled in from shard state at shutdown).
    pub rejected: u64,
    pub batches: u64,
    pub latency_us: Summary,
    pub batch_sizes: Summary,
    pub engine_us: Summary,
    /// Queue depth sampled at every batch release.
    pub queue_depth: Summary,
}

impl ServerMetrics {
    pub fn throughput_rps(&self, elapsed: Duration) -> f64 {
        self.completed as f64 / elapsed.as_secs_f64().max(1e-12)
    }
}

/// A handle to a running single-engine server. The engine is
/// **constructed inside the worker thread** (PJRT client handles are not
/// `Send`), so `start` takes a factory closure rather than an engine
/// value. Internally this is a 1-shard [`Fleet`] with an unbounded queue.
pub struct Server {
    fleet: Fleet,
}

impl Server {
    /// Spawn the serving loop; `make_engine` runs on the worker thread.
    pub fn start<F>(make_engine: F, policy: BatchPolicy) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
    {
        // Adapt the one-shot factory to the fleet's per-shard factory;
        // with exactly one shard it is called exactly once.
        let cell = Mutex::new(Some(make_engine));
        let fleet = Fleet::start(
            FleetConfig {
                shards: 1,
                policy: DispatchPolicy::RoundRobin,
                batch: policy,
                queue_cap: usize::MAX,
                ..FleetConfig::default()
            },
            move |_shard| {
                let f = cell.lock().unwrap().take().context("single-shard factory reused")?;
                f()
            },
        )?;
        Ok(Server { fleet })
    }

    /// Submit a request; returns the channel the reply arrives on.
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Reply>> {
        self.fleet.submit(input).map_err(anyhow::Error::from)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, input: Vec<f32>) -> Result<Reply> {
        self.fleet.infer(input)
    }

    /// Stop the worker and collect metrics.
    pub fn shutdown(self) -> Result<ServerMetrics> {
        let metrics = self.fleet.shutdown()?;
        metrics.shards.into_iter().next().context("no shard metrics")
    }
}

/// Synthetic Poisson arrival generator (the edge workload driver).
pub struct SyntheticLoad {
    pub rate_rps: f64,
    pub rng: Rng,
}

impl SyntheticLoad {
    pub fn new(rate_rps: f64, seed: u64) -> SyntheticLoad {
        SyntheticLoad { rate_rps, rng: Rng::new(seed) }
    }

    /// Next inter-arrival gap.
    pub fn next_gap(&mut self) -> Duration {
        Duration::from_secs_f64(self.rng.exponential(self.rate_rps))
    }

    /// A random input vector in the INT4-friendly [-1, 1] range.
    pub fn next_input(&mut self, dim: usize) -> Vec<f32> {
        (0..dim).map(|_| self.rng.uniform(-1.0, 1.0)).collect()
    }
}

/// A fixed pool of pre-generated inputs drawn with Zipf-skewed
/// popularity — the repeated-request workload a result cache exists
/// for. [`SyntheticLoad::next_input`] never repeats an input, so the
/// cache-enabled fleet driver and the `fleet_scaling` bench sample from
/// one of these instead: entry `k` is drawn with weight
/// `1 / (k + 1)^exponent`, making entry 0 the hot key.
pub struct InputPool {
    inputs: Vec<Vec<f32>>,
    /// Cumulative (unnormalized) Zipf weights, one per pool entry.
    cdf: Vec<f64>,
}

impl InputPool {
    pub fn zipf(dim: usize, n: usize, exponent: f64, seed: u64) -> InputPool {
        let mut rng = Rng::new(seed);
        let n = n.max(1);
        let inputs =
            (0..n).map(|_| (0..dim).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(exponent);
            cdf.push(acc);
        }
        InputPool { inputs, cdf }
    }

    /// Draw one input by Zipf popularity (cloned — requests take
    /// ownership of their input).
    pub fn sample(&self, rng: &mut Rng) -> Vec<f32> {
        let total = *self.cdf.last().expect("pool is never empty");
        let u = rng.f64() * total;
        let i = self.cdf.partition_point(|&c| c < u).min(self.inputs.len() - 1);
        self.inputs[i].clone()
    }

    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::emit::{compile_packed_layers, synthetic_packed_network};
    use crate::coordinator::engine::ApuEngine;
    use crate::sim::{Apu, ApuConfig};

    fn test_engine() -> Box<dyn Engine> {
        let layers = synthetic_packed_network(&[16, 20, 12], 4, 4, 5).unwrap();
        let program = compile_packed_layers("t", &layers, 0.2, 4, 4).unwrap();
        let apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        Box::new(ApuEngine::new(apu, &program).unwrap())
    }

    #[test]
    fn serves_requests_and_collects_metrics() {
        let server = Server::start(
            || Ok(test_engine()),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        )
        .unwrap();
        let mut load = SyntheticLoad::new(1000.0, 7);
        let receivers: Vec<_> = (0..20).map(|_| server.submit(load.next_input(16)).unwrap()).collect();
        for rx in receivers {
            let reply = rx.recv().unwrap();
            assert_eq!(reply.shard, 0);
            assert_eq!(reply.output.unwrap().len(), 12);
        }
        let metrics = server.shutdown().unwrap();
        assert_eq!(metrics.completed, 20);
        assert_eq!(metrics.failed, 0);
        assert!(metrics.batches >= 5); // max_batch 4 → at least 5 batches
        assert!(metrics.latency_us.mean() > 0.0);
    }

    #[test]
    fn reply_batch_size_is_the_ride_size() {
        // Submit a burst and hold the worker off with a long max_wait so
        // everything rides one batch: each reply must report that batch's
        // size, not the cumulative number of batches served.
        let server = Server::start(
            || Ok(test_engine()),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) },
        )
        .unwrap();
        let mut load = SyntheticLoad::new(1e9, 21);
        let rxs: Vec<_> = (0..8).map(|_| server.submit(load.next_input(16)).unwrap()).collect();
        for rx in rxs {
            let reply = rx.recv().unwrap();
            assert!(
                (1..=8).contains(&reply.batch_size),
                "batch_size {} out of range",
                reply.batch_size
            );
        }
        // A trailing solo request rides a batch of exactly 1.
        let reply = server.infer(load.next_input(16)).unwrap();
        assert_eq!(reply.batch_size, 1);
        server.shutdown().unwrap();
    }

    #[test]
    fn no_request_lost_under_burst() {
        let server = Server::start(
            || Ok(test_engine()),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
        )
        .unwrap();
        let mut load = SyntheticLoad::new(1e6, 8);
        let n = 100;
        let receivers: Vec<_> = (0..n).map(|_| server.submit(load.next_input(16)).unwrap()).collect();
        let got = receivers.into_iter().filter(|rx| rx.recv().is_ok()).count();
        assert_eq!(got, n);
        let metrics = server.shutdown().unwrap();
        assert_eq!(metrics.completed, n as u64);
    }

    #[test]
    fn synthetic_load_rates() {
        let mut l = SyntheticLoad::new(100.0, 3);
        let mean: f64 = (0..2000).map(|_| l.next_gap().as_secs_f64()).sum::<f64>() / 2000.0;
        assert!((mean - 0.01).abs() < 0.002, "mean gap {mean}");
        assert_eq!(l.next_input(5).len(), 5);
    }

    #[test]
    fn input_pool_skews_toward_the_hot_entry() {
        let pool = InputPool::zipf(4, 16, 1.1, 42);
        assert_eq!(pool.len(), 16);
        assert!(!pool.is_empty());
        let hot = pool.inputs[0].clone();
        let cold = pool.inputs[15].clone();
        let mut rng = Rng::new(7);
        let (mut hot_n, mut cold_n) = (0, 0);
        for _ in 0..2000 {
            let x = pool.sample(&mut rng);
            assert_eq!(x.len(), 4);
            if x == hot {
                hot_n += 1;
            } else if x == cold {
                cold_n += 1;
            }
        }
        assert!(hot_n > 8 * cold_n.max(1), "hot {hot_n} vs cold {cold_n}: no skew");
    }
}
