//! The serving loop: worker thread + request channel + metrics.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{BatchPolicy, Batcher};
use super::engine::Engine;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// One inference request.
struct Request {
    input: Vec<f32>,
    submitted: Instant,
    reply: mpsc::Sender<Reply>,
}

/// The response handed back to the caller.
#[derive(Debug)]
pub struct Reply {
    pub output: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
}

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub completed: u64,
    pub batches: u64,
    pub latency_us: Summary,
    pub batch_sizes: Summary,
    pub engine_us: Summary,
}

impl ServerMetrics {
    pub fn throughput_rps(&self, elapsed: Duration) -> f64 {
        self.completed as f64 / elapsed.as_secs_f64().max(1e-12)
    }
}

/// A handle to a running server. The engine is **constructed inside the
/// worker thread** (PJRT client handles are not `Send`), so `start` takes
/// a factory closure rather than an engine value.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<JoinHandle<ServerMetrics>>,
}

impl Server {
    /// Spawn the serving loop; `make_engine` runs on the worker thread.
    pub fn start<F>(make_engine: F, policy: BatchPolicy) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::spawn(move || {
            let engine = match make_engine() {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return ServerMetrics::default();
                }
            };
            serve_loop(engine, policy, rx)
        });
        ready_rx.recv().context("worker died during engine construction")??;
        Ok(Server { tx: Some(tx), worker: Some(worker) })
    }

    /// Submit a request; returns the channel the reply arrives on.
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Reply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .context("server stopped")?
            .send(Request { input, submitted: Instant::now(), reply: rtx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rrx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, input: Vec<f32>) -> Result<Reply> {
        let rx = self.submit(input)?;
        rx.recv().context("server dropped request")
    }

    /// Stop the worker and collect metrics.
    pub fn shutdown(mut self) -> Result<ServerMetrics> {
        drop(self.tx.take());
        let worker = self.worker.take().context("already shut down")?;
        worker.join().map_err(|_| anyhow::anyhow!("worker panicked"))
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn serve_loop(
    mut engine: Box<dyn Engine>,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Request>,
) -> ServerMetrics {
    let mut metrics = ServerMetrics::default();
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut open = true;
    while open || !batcher.is_empty() {
        // Fill the batcher: block briefly for the first request, then
        // drain whatever is already queued.
        if batcher.is_empty() && open {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(r) => batcher.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    continue;
                }
            }
        }
        loop {
            match rx.try_recv() {
                Ok(r) => batcher.push(r),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        let now = Instant::now();
        if !batcher.ready(now) && open {
            if let Some(d) = batcher.next_deadline(now) {
                // Wait out the batching window (or a new arrival).
                match rx.recv_timeout(d.min(Duration::from_millis(5))) {
                    Ok(r) => batcher.push(r),
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                }
                continue;
            }
            continue;
        }
        let batch = batcher.take_batch();
        if batch.is_empty() {
            continue;
        }
        let inputs: Vec<Vec<f32>> = batch.iter().map(|p| p.payload.input.clone()).collect();
        let t0 = Instant::now();
        let outputs = match engine.infer_batch(&inputs) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("engine error, dropping batch: {e:#}");
                continue;
            }
        };
        let engine_time = t0.elapsed();
        metrics.engine_us.add(engine_time.as_secs_f64() * 1e6);
        metrics.batches += 1;
        metrics.batch_sizes.add(batch.len() as f64);
        let done = Instant::now();
        for (pending, output) in batch.into_iter().zip(outputs) {
            let latency = done.duration_since(pending.payload.submitted);
            metrics.completed += 1;
            metrics.latency_us.add(latency.as_secs_f64() * 1e6);
            let _ = pending.payload.reply.send(Reply { output, latency, batch_size: metrics.batch_sizes.count() as usize });
        }
    }
    drop(engine);
    metrics
}

/// Synthetic Poisson arrival generator (the edge workload driver).
pub struct SyntheticLoad {
    pub rate_rps: f64,
    pub rng: Rng,
}

impl SyntheticLoad {
    pub fn new(rate_rps: f64, seed: u64) -> SyntheticLoad {
        SyntheticLoad { rate_rps, rng: Rng::new(seed) }
    }

    /// Next inter-arrival gap.
    pub fn next_gap(&mut self) -> Duration {
        Duration::from_secs_f64(self.rng.exponential(self.rate_rps))
    }

    /// A random input vector in the INT4-friendly [-1, 1] range.
    pub fn next_input(&mut self, dim: usize) -> Vec<f32> {
        (0..dim).map(|_| self.rng.uniform(-1.0, 1.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::emit::{compile_packed_layers, synthetic_packed_network};
    use crate::coordinator::engine::ApuEngine;
    use crate::sim::{Apu, ApuConfig};

    fn test_engine() -> Box<dyn Engine> {
        let layers = synthetic_packed_network(&[16, 20, 12], 4, 4, 5).unwrap();
        let program = compile_packed_layers("t", &layers, 0.2, 4, 4).unwrap();
        let apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        Box::new(ApuEngine::new(apu, &program).unwrap())
    }

    #[test]
    fn serves_requests_and_collects_metrics() {
        let server = Server::start(
            || Ok(test_engine()),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        )
        .unwrap();
        let mut load = SyntheticLoad::new(1000.0, 7);
        let receivers: Vec<_> = (0..20).map(|_| server.submit(load.next_input(16)).unwrap()).collect();
        for rx in receivers {
            let reply = rx.recv().unwrap();
            assert_eq!(reply.output.len(), 12);
        }
        let metrics = server.shutdown().unwrap();
        assert_eq!(metrics.completed, 20);
        assert!(metrics.batches >= 5); // max_batch 4 → at least 5 batches
        assert!(metrics.latency_us.mean() > 0.0);
    }

    #[test]
    fn no_request_lost_under_burst() {
        let server = Server::start(
            || Ok(test_engine()),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
        )
        .unwrap();
        let mut load = SyntheticLoad::new(1e6, 8);
        let n = 100;
        let receivers: Vec<_> = (0..n).map(|_| server.submit(load.next_input(16)).unwrap()).collect();
        let got = receivers.into_iter().filter(|rx| rx.recv().is_ok()).count();
        assert_eq!(got, n);
        let metrics = server.shutdown().unwrap();
        assert_eq!(metrics.completed, n as u64);
    }

    #[test]
    fn synthetic_load_rates() {
        let mut l = SyntheticLoad::new(100.0, 3);
        let mean: f64 = (0..2000).map(|_| l.next_gap().as_secs_f64()).sum::<f64>() / 2000.0;
        assert!((mean - 0.01).abs() < 0.002, "mean gap {mean}");
        assert_eq!(l.next_input(5).len(), 5);
    }
}
