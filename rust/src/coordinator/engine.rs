//! Inference engines the coordinator can drive.

use anyhow::Result;

use crate::runtime::{Executable, Manifest, Runtime};
use crate::sim::Apu;

/// Anything that can run a batch of inputs to outputs.
pub trait Engine {
    fn name(&self) -> &str;
    fn input_dim(&self) -> usize;
    fn output_dim(&self) -> usize;
    /// Run a batch; must return one output per input, in order.
    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
}

/// The cycle-accurate APU simulator as a serving engine. Single-sample
/// hardware: batches are processed back to back (the paper's accelerator
/// is a batch-1 design; batching only amortizes coordinator overhead).
pub struct ApuEngine {
    apu: Apu,
    din: usize,
    dout: usize,
    name: String,
}

impl ApuEngine {
    pub fn new(mut apu: Apu, program: impl crate::sim::IntoProgramArc) -> Result<ApuEngine> {
        let program = program.into_program_arc();
        apu.load(std::sync::Arc::clone(&program))?;
        Ok(ApuEngine { apu, din: program.din, dout: program.dout, name: format!("apu-sim:{}", program.name) })
    }

    /// Build a serving engine for a pipeline-compiled network: the
    /// simulator instance is sized from the same machine model the
    /// compiler mapped against (`apu fleet --model zoo:<name>`).
    pub fn from_compiled(compiled: &crate::compiler::CompiledNetwork) -> Result<ApuEngine> {
        let apu = Apu::new(compiled.model.apu_config());
        ApuEngine::new(apu, &compiled.program)
    }

    /// Build a serving engine from a catalog entry: the simulator is
    /// sized to the entry's machine and loads the *shared* program and
    /// execution plan — no per-shard plan build, no program copy.
    pub fn from_entry(entry: &crate::coordinator::catalog::ModelEntry) -> Result<ApuEngine> {
        let mut apu = Apu::new(entry.machine.clone());
        apu.load_with_plan(&entry.program, entry.plan.clone())?;
        Ok(ApuEngine {
            apu,
            din: entry.program.din,
            dout: entry.program.dout,
            name: format!("apu-sim:{}", entry.name),
        })
    }

    pub fn stats(&self) -> &crate::sim::SimStats {
        self.apu.stats()
    }

    /// Set the lane-pool width for planned `run_batch` calls (bitwise
    /// invisible to outputs/stats; see `FleetConfig::threads_per_shard`).
    pub fn set_threads(&mut self, threads: usize) {
        self.apu.set_threads(threads);
    }
}

impl Engine for ApuEngine {
    fn name(&self) -> &str {
        &self.name
    }

    fn input_dim(&self) -> usize {
        self.din
    }

    fn output_dim(&self) -> usize {
        self.dout
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        // One planned run_batch call per flushed batch: the plan's
        // layer-steps execute across all lanes (falls back to sequential
        // interpretation when the program has no plan).
        let refs: Vec<&[f32]> = inputs.iter().map(|x| x.as_slice()).collect();
        self.apu.run_batch(&refs)
    }
}

/// The PJRT golden model as a serving engine: dispatches to the lowered
/// batch-8 artifact when a full batch is available, else batch-1.
pub struct GoldenEngine {
    exe_b1: Executable,
    exe_b8: Executable,
    din: usize,
    dout: usize,
}

impl GoldenEngine {
    pub fn from_artifacts(manifest: &Manifest, din: usize, dout: usize) -> Result<GoldenEngine> {
        let rt = Runtime::cpu()?;
        let exe_b1 = rt.load_hlo_text(manifest.hlo_path("lenet_b1")?)?;
        let exe_b8 = rt.load_hlo_text(manifest.hlo_path("lenet_b8")?)?;
        Ok(GoldenEngine { exe_b1, exe_b8, din, dout })
    }
}

impl Engine for GoldenEngine {
    fn name(&self) -> &str {
        "pjrt-golden"
    }

    fn input_dim(&self) -> usize {
        self.din
    }

    fn output_dim(&self) -> usize {
        self.dout
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(inputs.len());
        let mut i = 0;
        while i < inputs.len() {
            if inputs.len() - i >= 8 {
                // pack 8 inputs into the batch-8 executable
                let mut flat = Vec::with_capacity(8 * self.din);
                for x in &inputs[i..i + 8] {
                    flat.extend_from_slice(x);
                }
                let res = self.exe_b8.run_f32(&[(&flat, &[8, self.din as i64])])?;
                let logits = &res[0];
                for b in 0..8 {
                    out.push(logits[b * self.dout..(b + 1) * self.dout].to_vec());
                }
                i += 8;
            } else {
                let res = self.exe_b1.run_f32(&[(&inputs[i], &[1, self.din as i64])])?;
                out.push(res[0].clone());
                i += 1;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::emit::{compile_packed_layers, synthetic_packed_network};
    use crate::sim::ApuConfig;

    #[test]
    fn apu_engine_serves_batches() {
        let layers = synthetic_packed_network(&[16, 20, 12], 4, 4, 42).unwrap();
        let program = compile_packed_layers("t", &layers, 0.2, 4, 4).unwrap();
        let apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        let mut eng = ApuEngine::new(apu, &program).unwrap();
        assert_eq!(eng.input_dim(), 16);
        let inputs: Vec<Vec<f32>> = (0..3).map(|i| vec![0.1 * i as f32; 16]).collect();
        let out = eng.infer_batch(&inputs).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|o| o.len() == 12));
        assert_eq!(eng.stats().inferences, 3);
    }
}
