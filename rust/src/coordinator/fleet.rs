//! Sharded multi-engine serving fleet.
//!
//! Scaling *out* across engine replicas, not just batching into one: a
//! [`Fleet`] spawns N shard workers, each owning its own [`Engine`]
//! (constructed **inside** the worker thread via a factory closure —
//! PJRT client handles are not `Send`) and its own deadline-aware
//! [`Batcher`]. A pluggable [`Dispatcher`] routes each request to a
//! shard; bounded per-shard queues give explicit admission control
//! (reject-with-error instead of unbounded buffering), and shutdown
//! folds per-shard metrics into a [`FleetMetrics`] the SLO reporter
//! (`coordinator::slo`) turns into p50/p95/p99 / rejection-rate tables.
//!
//! The single-engine [`Server`](super::server::Server) is the 1-shard
//! special case of this module: it shares `serve_loop` and the shard
//! worker code path, with an effectively unbounded queue.
//!
//! Fleets are **model-keyed**: shards are organized into per-model
//! groups ([`Group`]), requests carry a [`ModelId`], and the dispatcher
//! selects only within the target model's group. [`Fleet::start`] is the
//! single-model case (one group, `"default"`); [`Fleet::start_catalog`]
//! builds one group per [`ModelCatalog`] entry, with every shard in a
//! group loading the catalog's *shared* program and execution plan.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::batcher::{BatchPolicy, Batcher, FlushReason};
use super::cache::{CacheFill, CacheStats, GroupCache, InputKeyer};
use super::catalog::{ModelCatalog, ModelId};
use super::dispatch::{DispatchPolicy, Dispatcher, ShardLoad};
use super::engine::Engine;
use super::server::{Reply, ServeError, ServerMetrics};
use crate::obs::metrics::{self, Counter, Gauge, Histogram, Registry};
use crate::obs::trace::{Tracer, PID_FLEET};
use crate::util::json::Json;
use crate::util::stats::Summary;

/// One inference request riding through a shard worker.
pub(super) struct Request {
    pub(super) input: Vec<f32>,
    /// The model this request targets (stamped onto its [`Reply`]).
    pub(super) model: ModelId,
    pub(super) submitted: Instant,
    pub(super) reply: mpsc::Sender<Reply>,
    /// Lifecycle trace context (present when the fleet has a tracer).
    pub(super) trace: Option<ReqTrace>,
    /// Present on a cache miss: the shard worker stores the successful
    /// output under this precomputed key when the reply goes out.
    pub(super) fill: Option<CacheFill>,
}

/// The `Reply::shard` sentinel for cache hits: a cached reply was never
/// dispatched, so it carries no real shard id.
pub const CACHE_SHARD: usize = usize::MAX;

/// Per-request lifecycle timestamps, µs on the fleet tracer's clock.
pub(super) struct ReqTrace {
    pub(super) id: u64,
    pub(super) enqueue_us: f64,
    pub(super) dequeue_us: Option<f64>,
}

/// Fleet sizing and policy knobs.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of shard workers (each with its own engine + batcher).
    pub shards: usize,
    /// How requests are routed to shards.
    pub policy: DispatchPolicy,
    /// Per-shard batching policy.
    pub batch: BatchPolicy,
    /// Per-shard bound on admitted-but-unbatched requests; a submit that
    /// lands on a shard at this depth is rejected, not buffered.
    pub queue_cap: usize,
    /// Metrics registry the shards register their counters/histograms
    /// into (defaults to the process-global registry; tests pass private
    /// ones).
    pub metrics: Arc<Registry>,
    /// When set, every request records its
    /// enqueue→dequeue→batch-assembly→engine-run→reply lifecycle as
    /// Chrome trace spans on this tracer.
    pub tracer: Option<Tracer>,
    /// Lane-pool width each shard's APU engine uses for planned batch
    /// execution (`Apu::set_threads`). Bitwise invisible to outputs and
    /// stats; 1 = sequential (no threads spawned). Only catalog-backed
    /// fleets apply it — engines from custom factories set their own.
    pub threads_per_shard: usize,
    /// Default result-cache capacity per model group, in entries; 0
    /// disables caching. Only catalog-backed fleets build caches (the
    /// keyer needs the entry's fingerprint/machine/quantizer); a catalog
    /// entry's own `cache_entries` overrides this default. Hits reply
    /// before admission control and touch none of the per-shard metrics
    /// — see the accounting rule in [`super::cache`].
    pub cache_entries: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 4,
            policy: DispatchPolicy::JoinShortestQueue,
            batch: BatchPolicy::default(),
            queue_cap: 256,
            metrics: metrics::global(),
            tracer: None,
            threads_per_shard: 1,
            cache_entries: 0,
        }
    }
}

/// Shared shard state the dispatcher and admission control read.
#[derive(Debug, Default)]
pub(super) struct ShardState {
    /// Admitted but not yet taken into an executing batch.
    queued: AtomicUsize,
    /// Admitted but not yet replied to (queued + executing).
    outstanding: AtomicUsize,
    /// Cleared when the engine factory fails or the worker exits.
    alive: AtomicBool,
    /// Requests refused by admission control at this shard.
    rejected: AtomicU64,
}

impl ShardState {
    fn new() -> ShardState {
        ShardState { alive: AtomicBool::new(true), ..Default::default() }
    }

    fn load(&self) -> ShardLoad {
        ShardLoad {
            queued: self.queued.load(Ordering::Relaxed),
            outstanding: self.outstanding.load(Ordering::Relaxed),
            alive: self.alive.load(Ordering::Relaxed),
        }
    }
}

/// One shard's handles into the metrics registry. Registered once at
/// fleet start; the worker thread and the submit path clone the handles
/// and update lock-free.
#[derive(Clone)]
pub(super) struct ShardInstruments {
    pub(super) enqueued: Counter,
    pub(super) completed: Counter,
    pub(super) engine_errors: Counter,
    pub(super) rejected: Counter,
    pub(super) queue_depth: Gauge,
    pub(super) latency_us: Histogram,
    pub(super) batch_size: Histogram,
    pub(super) engine_calls: Counter,
    pub(super) full_flushes: Counter,
    pub(super) deadline_flushes: Counter,
    pub(super) drain_flushes: Counter,
}

impl ShardInstruments {
    pub(super) fn register(reg: &Registry, model: &str, shard: usize) -> ShardInstruments {
        let s = shard.to_string();
        let l: &[(&str, &str)] = &[("model", model), ("shard", s.as_str())];
        ShardInstruments {
            enqueued: reg.counter(
                "apu_fleet_enqueued_total",
                "requests admitted past admission control",
                l,
            ),
            completed: reg.counter(
                "apu_fleet_completed_total",
                "requests answered successfully",
                l,
            ),
            engine_errors: reg.counter(
                "apu_fleet_engine_errors_total",
                "requests answered with an engine error",
                l,
            ),
            rejected: reg.counter(
                "apu_fleet_rejected_total",
                "requests refused by admission control",
                l,
            ),
            queue_depth: reg.gauge(
                "apu_fleet_queue_depth",
                "admitted-but-unbatched requests at batch release",
                l,
            ),
            latency_us: reg.histogram(
                "apu_fleet_request_latency_us",
                "submit-to-reply latency, microseconds",
                &metrics::latency_buckets_us(),
                l,
            ),
            batch_size: reg.histogram(
                "apu_fleet_batch_size",
                "requests per released batch",
                &metrics::batch_buckets(),
                l,
            ),
            engine_calls: reg.counter(
                "apu_fleet_engine_calls_total",
                "engine invocations (one run_batch per flushed batch)",
                l,
            ),
            full_flushes: reg.counter(
                "apu_fleet_batch_full_flush_total",
                "batches released because they filled",
                l,
            ),
            deadline_flushes: reg.counter(
                "apu_fleet_batch_deadline_flush_total",
                "batches released by the batching deadline",
                l,
            ),
            drain_flushes: reg.counter(
                "apu_fleet_batch_drain_flush_total",
                "batches released by the shutdown drain",
                l,
            ),
        }
    }
}

struct Shard {
    tx: Option<mpsc::Sender<Request>>,
    state: Arc<ShardState>,
    ins: ShardInstruments,
    worker: Option<JoinHandle<ServerMetrics>>,
}

/// One model's slice of the fleet: the global shard ids serving it and
/// the dispatcher that routes within them. Each group has its own
/// dispatcher so round-robin cursors (and load comparisons) never mix
/// traffic across models.
pub struct Group {
    model: ModelId,
    label: String,
    shard_ids: Vec<usize>,
    dispatcher: Dispatcher,
    /// The model's result cache, when enabled for this group.
    cache: Option<GroupCache>,
}

impl Group {
    pub fn model(&self) -> ModelId {
        self.model
    }

    /// The model name used as the metrics/SLO label.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Global shard ids belonging to this group.
    pub fn shard_ids(&self) -> &[usize] {
        &self.shard_ids
    }

    /// Live snapshot of this group's result-cache counters; `None` when
    /// the group serves uncached.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }
}

/// Internal per-group start spec: label, shard count, and the result
/// cache to build (keyer + capacity), if any.
struct GroupSpec {
    label: String,
    count: usize,
    cache: Option<(InputKeyer, usize)>,
}

/// Why a submit was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission control: the selected shard's queue is at its bound.
    /// Load-blind policies (round-robin) can reject while other shards
    /// have room — that cost is exactly what the SLO tables surface.
    Rejected { shard: usize, depth: usize, cap: usize },
    /// No live shard to dispatch to (all engines failed or fleet stopped).
    Unavailable,
    /// The request targeted a model this fleet does not serve.
    UnknownModel { model: ModelId, models: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Rejected { shard, depth, cap } => {
                write!(f, "admission control rejected request: shard {shard} queue {depth}/{cap}")
            }
            SubmitError::Unavailable => write!(f, "no live shard available"),
            SubmitError::UnknownModel { model, models } => {
                write!(f, "{model} not served by this fleet ({models} models)")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Aggregated metrics for a whole fleet run.
#[derive(Debug)]
pub struct FleetMetrics {
    /// Per-shard serving metrics, indexed by shard id. Shards whose
    /// engine factory failed contribute an empty entry.
    pub shards: Vec<ServerMetrics>,
    /// `(shard id, error)` for shards whose engine factory failed.
    pub dead: Vec<(usize, String)>,
    /// The dispatch policy the run used.
    pub policy: DispatchPolicy,
    /// `(model label, global shard ids)` per model group, in [`ModelId`]
    /// order. Single-model fleets have one `"default"` group spanning
    /// every shard.
    pub groups: Vec<(String, Vec<usize>)>,
    /// Final result-cache counters per group, aligned with `groups`
    /// (`None` for groups that served uncached). Empty for fleets
    /// without any cache.
    pub cache: Vec<Option<CacheStats>>,
}

impl FleetMetrics {
    pub fn completed(&self) -> u64 {
        self.shards.iter().map(|s| s.completed).sum()
    }

    pub fn failed(&self) -> u64 {
        self.shards.iter().map(|s| s.failed).sum()
    }

    pub fn rejected(&self) -> u64 {
        self.shards.iter().map(|s| s.rejected).sum()
    }

    /// Fraction of arrivals (admitted + rejected) that were rejected.
    pub fn rejection_rate(&self) -> f64 {
        let arrivals = self.completed() + self.failed() + self.rejected();
        if arrivals == 0 {
            0.0
        } else {
            self.rejected() as f64 / arrivals as f64
        }
    }

    pub fn throughput_rps(&self, elapsed: Duration) -> f64 {
        self.completed() as f64 / elapsed.as_secs_f64().max(1e-12)
    }

    /// Fleet-wide latency distribution: the per-shard streams merged, so
    /// percentiles are exact rather than averaged across shards.
    pub fn fleet_latency_us(&self) -> Summary {
        let mut s = Summary::new();
        for sh in &self.shards {
            s.merge(&sh.latency_us);
        }
        s
    }
}

/// A handle to a running fleet of shard workers.
pub struct Fleet {
    shards: Vec<Shard>,
    groups: Vec<Group>,
    config: FleetConfig,
    dead: Vec<(usize, String)>,
}

impl Fleet {
    /// Spawn `config.shards` workers serving one model; `make_engine(shard_id)`
    /// runs on each worker thread (engines are built in-thread — PJRT
    /// handles are not `Send`). Shards whose factory fails are marked dead
    /// and skipped by the dispatcher; `start` errors only if *every*
    /// factory fails. This is the single-model case of
    /// [`Fleet::start_catalog`]: one `"default"` group spanning every shard.
    pub fn start<F>(config: FleetConfig, make_engine: F) -> Result<Fleet>
    where
        F: Fn(usize) -> Result<Box<dyn Engine>> + Send + Sync + 'static,
    {
        let n = config.shards;
        Fleet::start_grouped(
            config,
            vec![GroupSpec { label: "default".to_string(), count: n, cache: None }],
            Arc::new(move |shard, _model| make_engine(shard)),
        )
    }

    /// Spawn one shard group per catalog model: group `g` serves
    /// `catalog` entry `g` with `shards_per_model[g]` workers, each
    /// loading the catalog's shared program and execution plan (exactly
    /// one plan build per model process-wide). `config.shards` is
    /// ignored; the fleet size is the sum of `shards_per_model`.
    pub fn start_catalog(
        config: FleetConfig,
        catalog: Arc<ModelCatalog>,
        shards_per_model: &[usize],
    ) -> Result<Fleet> {
        if catalog.is_empty() {
            bail!("fleet catalog has no models");
        }
        if shards_per_model.len() != catalog.len() {
            bail!(
                "shards_per_model has {} entries for {} catalog models",
                shards_per_model.len(),
                catalog.len()
            );
        }
        let groups: Vec<GroupSpec> = catalog
            .iter()
            .zip(shards_per_model)
            .map(|((_, e), &n)| {
                // Per-model capacity override, else the fleet default;
                // 0 leaves the group uncached.
                let capacity = e.cache_entries.unwrap_or(config.cache_entries);
                let cache = (capacity > 0).then(|| (InputKeyer::for_entry(e), capacity));
                GroupSpec { label: e.name.clone(), count: n, cache }
            })
            .collect();
        let threads = config.threads_per_shard;
        Fleet::start_grouped(
            config,
            groups,
            Arc::new(move |_shard, model| {
                let mut engine = catalog.engine(model)?;
                engine.set_threads(threads);
                Ok(Box::new(engine) as Box<dyn Engine>)
            }),
        )
    }

    /// Shared start path: spawn `count` workers per group spec,
    /// assigning global shard ids group by group.
    fn start_grouped(
        config: FleetConfig,
        group_spec: Vec<GroupSpec>,
        factory: Arc<dyn Fn(usize, ModelId) -> Result<Box<dyn Engine>> + Send + Sync>,
    ) -> Result<Fleet> {
        let total: usize = group_spec.iter().map(|g| g.count).sum();
        if total == 0 {
            bail!("fleet needs at least one shard");
        }
        if group_spec.iter().any(|g| g.count == 0) {
            bail!("every model group needs at least one shard");
        }
        if config.queue_cap == 0 {
            bail!("queue_cap must be at least 1 (0 admits nothing)");
        }
        let mut shards = Vec::with_capacity(total);
        let mut ready = Vec::with_capacity(total);
        let mut groups = Vec::with_capacity(group_spec.len());
        for (g, GroupSpec { label, count, cache }) in group_spec.into_iter().enumerate() {
            let model = ModelId(g);
            let mut shard_ids = Vec::with_capacity(count);
            for _ in 0..count {
                let id = shards.len();
                shard_ids.push(id);
                let (tx, rx) = mpsc::channel::<Request>();
                let state = Arc::new(ShardState::new());
                let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
                let factory = Arc::clone(&factory);
                let batch = config.batch.clone();
                let worker_state = Arc::clone(&state);
                let ins = ShardInstruments::register(&config.metrics, &label, id);
                let worker_ins = ins.clone();
                let tracer = config.tracer.clone();
                let worker = std::thread::Builder::new()
                    .name(format!("apu-shard-{id}"))
                    .spawn(move || {
                        let engine = match factory(id, model) {
                            Ok(e) => {
                                let _ = ready_tx.send(Ok(()));
                                e
                            }
                            Err(e) => {
                                worker_state.alive.store(false, Ordering::Relaxed);
                                let _ = ready_tx.send(Err(e));
                                return ServerMetrics::default();
                            }
                        };
                        let tr = tracer.as_ref();
                        let metrics =
                            serve_loop(id, engine, batch, rx, &worker_state, &worker_ins, tr);
                        worker_state.alive.store(false, Ordering::Relaxed);
                        metrics
                    })
                    .with_context(|| format!("spawning shard {id}"))?;
                shards.push(Shard { tx: Some(tx), state, ins, worker: Some(worker) });
                ready.push(ready_rx);
            }
            let cache =
                cache.map(|(keyer, cap)| GroupCache::register(&config.metrics, &label, keyer, cap));
            groups.push(Group {
                model,
                label,
                shard_ids,
                dispatcher: Dispatcher::new(config.policy),
                cache,
            });
        }
        let mut dead = Vec::new();
        for (id, rx) in ready.into_iter().enumerate() {
            match rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => dead.push((id, format!("{e:#}"))),
                Err(_) => dead.push((id, "worker died during engine construction".into())),
            }
        }
        if dead.len() == total {
            let (id, err) = &dead[0];
            bail!("every shard engine failed to construct (shard {id}: {err})");
        }
        Ok(Fleet { shards, groups, config, dead })
    }

    pub fn config(&self) -> &FleetConfig {
        &self.config
    }

    /// Per-model shard groups, indexed by [`ModelId`].
    pub fn groups(&self) -> &[Group] {
        &self.groups
    }

    /// Look up the [`ModelId`] for a model label served by this fleet.
    pub fn model_id(&self, name: &str) -> Option<ModelId> {
        self.groups.iter().find(|g| g.label == name).map(|g| g.model)
    }

    /// Shards that failed engine construction, as `(shard id, error)`.
    pub fn dead_shards(&self) -> &[(usize, String)] {
        &self.dead
    }

    pub fn alive_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.state.load().alive).count()
    }

    /// Current per-shard load snapshot (what the dispatcher sees).
    pub fn shard_loads(&self) -> Vec<ShardLoad> {
        self.shards.iter().map(|s| s.state.load()).collect()
    }

    /// Route a request to the first model group (the whole fleet for
    /// single-model fleets). Admission control: if the selected shard's
    /// queue is at `queue_cap`, the request is rejected with an explicit
    /// error — it is never buffered beyond the bound.
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Reply>, SubmitError> {
        self.submit_to(ModelId(0), input)
    }

    /// Route a request to a shard of `model`'s group. The dispatcher
    /// selects only among that model's shards; other groups' load never
    /// influences (or is disturbed by) this request.
    pub fn submit_to(
        &self,
        model: ModelId,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Reply>, SubmitError> {
        let group = self
            .groups
            .get(model.0)
            .ok_or(SubmitError::UnknownModel { model, models: self.groups.len() })?;
        let submitted = Instant::now();
        // Result-cache check, deliberately *before* admission control: a
        // hit replies without ever touching a shard queue, so the JSQ
        // queue-depth signal and every per-shard metric see only real
        // engine traffic (the accounting rule in `coordinator::cache`).
        let mut fill = None;
        if let Some(cache) = &group.cache {
            match cache.keyer.key(&input) {
                Some(key) => {
                    if let Some(output) = cache.store.get(&key) {
                        cache.hits.inc();
                        let latency = submitted.elapsed();
                        cache.hit_latency_us.observe(latency.as_secs_f64() * 1e6);
                        let (rtx, rrx) = mpsc::channel();
                        let _ = rtx.send(Reply {
                            output: Ok(output),
                            latency,
                            batch_size: 0,
                            shard: CACHE_SHARD,
                            model,
                            cached: true,
                        });
                        return Ok(rrx);
                    }
                    cache.misses.inc();
                    fill = Some(CacheFill {
                        store: Arc::clone(&cache.store),
                        key,
                        evictions: cache.evictions.clone(),
                    });
                }
                // NaN input: never keyed, never stored (see cache docs).
                None => cache.bypass.inc(),
            }
        }
        let loads: Vec<ShardLoad> =
            group.shard_ids.iter().map(|&i| self.shards[i].state.load()).collect();
        let local = group.dispatcher.select(&loads).ok_or(SubmitError::Unavailable)?;
        let i = group.shard_ids[local];
        let state = &self.shards[i].state;
        // Reserve a queue slot (CAS so concurrent submitters cannot
        // overshoot the bound), or reject.
        let cap = self.config.queue_cap;
        let mut depth = state.queued.load(Ordering::Relaxed);
        loop {
            if depth >= cap {
                state.rejected.fetch_add(1, Ordering::Relaxed);
                self.shards[i].ins.rejected.inc();
                // The rejection carries shard id and observed queue depth
                // so callers can log actionable admission-control context.
                return Err(SubmitError::Rejected { shard: i, depth, cap });
            }
            match state.queued.compare_exchange_weak(
                depth,
                depth + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(observed) => depth = observed,
            }
        }
        state.outstanding.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        let trace = self
            .config
            .tracer
            .as_ref()
            .map(|t| ReqTrace { id: t.next_id(), enqueue_us: t.now_us(), dequeue_us: None });
        let req = Request { input, model, submitted, reply: rtx, trace, fill };
        let sent = match self.shards[i].tx.as_ref() {
            Some(tx) => tx.send(req).is_ok(),
            None => false,
        };
        if sent {
            self.shards[i].ins.enqueued.inc();
        }
        if !sent {
            // Worker exited underneath us: roll the reservation back and
            // surface unavailability instead of hanging the caller.
            state.queued.fetch_sub(1, Ordering::Relaxed);
            state.outstanding.fetch_sub(1, Ordering::Relaxed);
            state.alive.store(false, Ordering::Relaxed);
            return Err(SubmitError::Unavailable);
        }
        Ok(rrx)
    }

    /// Blocking convenience: submit and wait for the reply.
    pub fn infer(&self, input: Vec<f32>) -> Result<Reply> {
        let rx = self.submit(input).map_err(anyhow::Error::from)?;
        rx.recv().context("fleet dropped request")
    }

    /// Blocking convenience: submit to `model` and wait for the reply.
    pub fn infer_model(&self, model: ModelId, input: Vec<f32>) -> Result<Reply> {
        let rx = self.submit_to(model, input).map_err(anyhow::Error::from)?;
        rx.recv().context("fleet dropped request")
    }

    /// Stop all workers (draining their queues) and collect metrics.
    pub fn shutdown(mut self) -> Result<FleetMetrics> {
        let mut out = Vec::with_capacity(self.shards.len());
        for shard in &mut self.shards {
            drop(shard.tx.take());
        }
        for shard in &mut self.shards {
            let worker = shard.worker.take().context("fleet already shut down")?;
            let mut m = worker.join().map_err(|_| anyhow::anyhow!("shard worker panicked"))?;
            m.rejected = shard.state.rejected.load(Ordering::Relaxed);
            out.push(m);
        }
        let groups = self
            .groups
            .iter()
            .map(|g| (g.label.clone(), g.shard_ids.clone()))
            .collect();
        let cache: Vec<Option<CacheStats>> = self.groups.iter().map(Group::cache_stats).collect();
        Ok(FleetMetrics {
            shards: out,
            dead: std::mem::take(&mut self.dead),
            policy: self.config.policy,
            groups,
            cache: if cache.iter().any(Option::is_some) { cache } else { Vec::new() },
        })
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for shard in &mut self.shards {
            drop(shard.tx.take());
        }
        for shard in &mut self.shards {
            if let Some(w) = shard.worker.take() {
                let _ = w.join();
            }
        }
    }
}

/// Stamp the dequeue timestamp the moment the worker pulls a request off
/// its channel.
fn mark_dequeue(mut r: Request, tracer: Option<&Tracer>) -> Request {
    if let Some(tr) = tracer {
        if let Some(t) = r.trace.as_mut() {
            t.dequeue_us = Some(tr.now_us());
        }
    }
    r
}

/// Record one request's whole-lifecycle span (enqueue → reply), with the
/// intermediate timestamps in `args` for the trace viewer's detail pane.
#[allow(clippy::too_many_arguments)]
fn record_request_span(
    tracer: &Tracer,
    shard: usize,
    req: &Request,
    ok: bool,
    batch_size: usize,
    assembly_us: f64,
    engine_start_us: f64,
    engine_end_us: f64,
) {
    let Some(t) = req.trace.as_ref() else {
        return;
    };
    let reply_us = tracer.now_us();
    tracer.span(
        "request",
        "fleet",
        PID_FLEET,
        shard as u64,
        t.enqueue_us,
        (reply_us - t.enqueue_us).max(0.0),
        vec![
            ("req".to_string(), Json::Int(t.id as i64)),
            ("ok".to_string(), Json::Bool(ok)),
            ("batch".to_string(), Json::Int(batch_size as i64)),
            ("enqueue_us".to_string(), Json::num(t.enqueue_us)),
            ("dequeue_us".to_string(), t.dequeue_us.map(Json::num).unwrap_or(Json::Null)),
            ("assembly_us".to_string(), Json::num(assembly_us)),
            ("engine_start_us".to_string(), Json::num(engine_start_us)),
            ("engine_end_us".to_string(), Json::num(engine_end_us)),
            ("reply_us".to_string(), Json::num(reply_us)),
        ],
    );
}

/// The shard worker: drain the channel into the batcher, release batches
/// by the batching policy, run the engine, reply per request. Shared by
/// the fleet shards and the single-engine `Server` (its 1-shard case).
pub(super) fn serve_loop(
    shard: usize,
    mut engine: Box<dyn Engine>,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Request>,
    state: &ShardState,
    ins: &ShardInstruments,
    tracer: Option<&Tracer>,
) -> ServerMetrics {
    let mut metrics = ServerMetrics::default();
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut open = true;
    while open || !batcher.is_empty() {
        // Fill the batcher: block briefly for the first request, then
        // drain whatever is already queued.
        if batcher.is_empty() && open {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(r) => batcher.push(mark_dequeue(r, tracer)),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    continue;
                }
            }
        }
        loop {
            match rx.try_recv() {
                Ok(r) => batcher.push(mark_dequeue(r, tracer)),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        let now = Instant::now();
        if !batcher.ready(now) && open {
            if let Some(d) = batcher.next_deadline(now) {
                // Wait out the batching window (or a new arrival).
                match rx.recv_timeout(d.min(Duration::from_millis(5))) {
                    Ok(r) => batcher.push(mark_dequeue(r, tracer)),
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                }
                continue;
            }
            continue;
        }
        let reason = batcher.flush_reason(now);
        let batch = batcher.take_batch();
        if batch.is_empty() {
            continue;
        }
        // `None` here means the loop fell through the `open` check: the
        // channel closed and the remainder is being drained at shutdown.
        match reason {
            Some(FlushReason::Full) => ins.full_flushes.inc(),
            Some(FlushReason::Deadline) => ins.deadline_flushes.inc(),
            None => ins.drain_flushes.inc(),
        }
        let assembly_us = tracer.map(|t| t.now_us()).unwrap_or(0.0);
        // Depth at release time (the batch members are still counted —
        // the decrement below is what frees their admission slots).
        let depth = state.queued.load(Ordering::Relaxed);
        metrics.queue_depth.add(depth as f64);
        ins.queue_depth.set(depth as f64);
        state.queued.fetch_sub(batch.len(), Ordering::Relaxed);
        let inputs: Vec<Vec<f32>> = batch.iter().map(|p| p.payload.input.clone()).collect();
        let t0 = Instant::now();
        let engine_start_us = tracer.map(|t| t.now_us()).unwrap_or(0.0);
        ins.engine_calls.inc();
        let result = engine.infer_batch(&inputs);
        let engine_time = t0.elapsed();
        let engine_end_us = tracer.map(|t| t.now_us()).unwrap_or(0.0);
        metrics.engine_us.add(engine_time.as_secs_f64() * 1e6);
        metrics.batches += 1;
        metrics.batch_sizes.add(batch.len() as f64);
        ins.batch_size.observe(batch.len() as f64);
        let batch_size = batch.len();
        if let Some(tr) = tracer {
            tr.span(
                "engine-run",
                "fleet",
                PID_FLEET,
                shard as u64,
                engine_start_us,
                engine_time.as_secs_f64() * 1e6,
                vec![
                    ("shard".to_string(), Json::Int(shard as i64)),
                    ("batch".to_string(), Json::Int(batch_size as i64)),
                ],
            );
        }
        let done = Instant::now();
        match result {
            Ok(outputs) => {
                for (mut pending, output) in batch.into_iter().zip(outputs) {
                    // A miss that carried a fill populates the cache on
                    // its way out; the stored bytes are the verbatim
                    // reply (planned runs are input-deterministic).
                    if let Some(fill) = pending.payload.fill.take() {
                        fill.evictions.add(fill.store.put(fill.key, output.clone()));
                    }
                    let latency = done.duration_since(pending.payload.submitted);
                    metrics.completed += 1;
                    metrics.latency_us.add(latency.as_secs_f64() * 1e6);
                    ins.completed.inc();
                    ins.latency_us.observe(latency.as_secs_f64() * 1e6);
                    state.outstanding.fetch_sub(1, Ordering::Relaxed);
                    if let Some(tr) = tracer {
                        record_request_span(
                            tr,
                            shard,
                            &pending.payload,
                            true,
                            batch_size,
                            assembly_us,
                            engine_start_us,
                            engine_end_us,
                        );
                    }
                    let _ = pending.payload.reply.send(Reply {
                        output: Ok(output),
                        latency,
                        batch_size,
                        shard,
                        model: pending.payload.model,
                        cached: false,
                    });
                }
            }
            Err(e) => {
                // A failed batch must not strand its callers: every
                // request gets an explicit error reply, and the failure
                // is counted and logged instead of silently dropped.
                let msg = format!("{e:#}");
                metrics.failed += batch_size as u64;
                ins.engine_errors.add(batch_size as u64);
                eprintln!("shard {shard}: engine error on batch of {batch_size}: {msg}");
                for pending in batch {
                    let latency = done.duration_since(pending.payload.submitted);
                    state.outstanding.fetch_sub(1, Ordering::Relaxed);
                    if let Some(tr) = tracer {
                        record_request_span(
                            tr,
                            shard,
                            &pending.payload,
                            false,
                            batch_size,
                            assembly_us,
                            engine_start_us,
                            engine_end_us,
                        );
                    }
                    // The fill (if any) is dropped with the request:
                    // failed outputs never enter the cache.
                    let _ = pending.payload.reply.send(Reply {
                        output: Err(ServeError::Engine(msg.clone())),
                        latency,
                        batch_size,
                        shard,
                        model: pending.payload.model,
                        cached: false,
                    });
                }
            }
        }
    }
    drop(engine);
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::emit::{compile_packed_layers, synthetic_packed_network};
    use crate::coordinator::engine::ApuEngine;
    use crate::coordinator::server::SyntheticLoad;
    use crate::sim::{Apu, ApuConfig};

    fn test_engine(seed: u64) -> Result<Box<dyn Engine>> {
        let layers = synthetic_packed_network(&[16, 20, 12], 4, 4, seed)?;
        let program = compile_packed_layers("fleet-test", &layers, 0.2, 4, 4)?;
        let apu = Apu::new(ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 });
        Ok(Box::new(ApuEngine::new(apu, &program)?))
    }

    fn config(shards: usize, policy: DispatchPolicy, cap: usize) -> FleetConfig {
        FleetConfig {
            shards,
            policy,
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
            queue_cap: cap,
            // private registry: unit tests must not race on the global one
            metrics: Arc::new(Registry::new()),
            ..FleetConfig::default()
        }
    }

    #[test]
    fn fleet_serves_across_shards() {
        let fleet =
            Fleet::start(config(3, DispatchPolicy::RoundRobin, 1024), |_| test_engine(5)).unwrap();
        let mut load = SyntheticLoad::new(1000.0, 7);
        let rxs: Vec<_> = (0..30).map(|_| fleet.submit(load.next_input(16)).unwrap()).collect();
        for rx in rxs {
            let reply = rx.recv().unwrap();
            assert_eq!(reply.output.unwrap().len(), 12);
            assert!(reply.shard < 3);
        }
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.completed(), 30);
        assert_eq!(m.rejected(), 0);
        // round-robin: every shard saw exactly a third of the traffic
        for sh in &m.shards {
            assert_eq!(sh.completed, 10);
        }
    }

    #[test]
    fn admission_control_rejects_at_bound() {
        // An engine that blocks until released, so queues actually fill.
        struct Stalled(mpsc::Receiver<()>);
        impl Engine for Stalled {
            fn name(&self) -> &str {
                "stalled"
            }
            fn input_dim(&self) -> usize {
                1
            }
            fn output_dim(&self) -> usize {
                1
            }
            fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                let _ = self.0.recv();
                Ok(inputs.to_vec())
            }
        }
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = std::sync::Mutex::new(Some(gate_rx));
        let cap = 4;
        let fleet = Fleet::start(
            FleetConfig {
                shards: 1,
                policy: DispatchPolicy::JoinShortestQueue,
                batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(1) },
                queue_cap: cap,
                metrics: Arc::new(Registry::new()),
                ..FleetConfig::default()
            },
            move |_| Ok(Box::new(Stalled(gate.lock().unwrap().take().unwrap())) as Box<dyn Engine>),
        )
        .unwrap();
        // Saturate: the worker takes one request into an executing batch
        // and stalls; everything else must queue up to the bound, after
        // which submits are rejected rather than buffered.
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for _ in 0..64 {
            match fleet.submit(vec![0.5]) {
                Ok(rx) => accepted.push(rx),
                Err(SubmitError::Rejected { cap: c, .. }) => {
                    assert_eq!(c, cap);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected > 0, "saturation must trigger admission control");
        assert!(accepted.len() <= cap + 1, "bound overshot: {} admitted", accepted.len());
        // Release the engine; every admitted request must still complete.
        for _ in 0..accepted.len() {
            let _ = gate_tx.send(());
        }
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.completed(), accepted.len() as u64);
        assert_eq!(m.rejected(), rejected as u64);
        for rx in accepted {
            assert!(rx.recv().unwrap().output.is_ok());
        }
    }

    #[test]
    fn engine_errors_reply_instead_of_dropping() {
        struct Flaky(u32);
        impl Engine for Flaky {
            fn name(&self) -> &str {
                "flaky"
            }
            fn input_dim(&self) -> usize {
                1
            }
            fn output_dim(&self) -> usize {
                1
            }
            fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                self.0 += 1;
                if self.0 % 2 == 0 {
                    bail!("transient engine fault");
                }
                Ok(inputs.to_vec())
            }
        }
        let reg = Arc::new(Registry::new());
        let fleet = Fleet::start(
            FleetConfig {
                shards: 1,
                policy: DispatchPolicy::RoundRobin,
                batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(1) },
                queue_cap: 1024,
                metrics: Arc::clone(&reg),
                ..FleetConfig::default()
            },
            |_| Ok(Box::new(Flaky(0)) as Box<dyn Engine>),
        )
        .unwrap();
        let n = 20;
        let rxs: Vec<_> = (0..n).map(|_| fleet.submit(vec![1.0]).unwrap()).collect();
        let mut ok = 0;
        let mut failed = 0;
        for rx in rxs {
            match rx.recv().unwrap().output {
                Ok(_) => ok += 1,
                Err(ServeError::Engine(msg)) => {
                    assert!(msg.contains("transient engine fault"));
                    failed += 1;
                }
            }
        }
        assert_eq!(ok + failed, n);
        assert!(failed > 0, "every other batch must fail");
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.completed(), ok as u64);
        assert_eq!(m.failed(), failed as u64);
        // the registry's view must agree with the dispatcher accounting
        assert_eq!(reg.counter_total("apu_fleet_engine_errors_total"), failed as u64);
        assert_eq!(reg.counter_total("apu_fleet_completed_total"), ok as u64);
        assert_eq!(reg.counter_total("apu_fleet_enqueued_total"), n as u64);
        assert_eq!(reg.counter_total("apu_fleet_rejected_total"), 0);
    }

    #[test]
    fn partial_factory_failure_degrades_not_dies() {
        let fleet = Fleet::start(config(4, DispatchPolicy::LeastOutstanding, 1024), |id| {
            if id == 2 {
                bail!("shard 2 hardware absent");
            }
            test_engine(11)
        })
        .unwrap();
        assert_eq!(fleet.alive_shards(), 3);
        assert_eq!(fleet.dead_shards().len(), 1);
        assert_eq!(fleet.dead_shards()[0].0, 2);
        let mut load = SyntheticLoad::new(1000.0, 13);
        let rxs: Vec<_> = (0..24).map(|_| fleet.submit(load.next_input(16)).unwrap()).collect();
        for rx in rxs {
            let reply = rx.recv().unwrap();
            assert!(reply.output.is_ok());
            assert_ne!(reply.shard, 2, "dead shard must not receive traffic");
        }
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.completed(), 24);
        assert_eq!(m.shards[2].completed, 0);
        assert_eq!(m.dead.len(), 1);
    }

    #[test]
    fn all_factories_failing_errors_start() {
        let r = Fleet::start(config(3, DispatchPolicy::RoundRobin, 16), |id| {
            bail!("shard {id} boom")
        });
        assert!(r.is_err());
        assert!(format!("{:#}", r.err().unwrap()).contains("every shard engine failed"));
    }

    #[test]
    fn catalog_fleet_routes_per_model() {
        let cfg = ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 };
        let mut cat = ModelCatalog::new();
        // distinct output dims so cross-model mixups would be visible
        let la = synthetic_packed_network(&[16, 20, 12], 4, 4, 31).unwrap();
        let a = cat
            .add_program(
                "model-a",
                Arc::new(compile_packed_layers("model-a", &la, 0.2, 4, 4).unwrap()),
                cfg.clone(),
            )
            .unwrap();
        let lb = synthetic_packed_network(&[16, 18, 10], 4, 4, 32).unwrap();
        let b = cat
            .add_program(
                "model-b",
                Arc::new(compile_packed_layers("model-b", &lb, 0.2, 4, 4).unwrap()),
                cfg,
            )
            .unwrap();
        let fleet = Fleet::start_catalog(
            config(0, DispatchPolicy::RoundRobin, 1024),
            Arc::new(cat),
            &[2, 1],
        )
        .unwrap();
        assert_eq!(fleet.groups().len(), 2);
        assert_eq!(fleet.model_id("model-b"), Some(b));
        let mut load = SyntheticLoad::new(1000.0, 9);
        for _ in 0..6 {
            let ra = fleet.infer_model(a, load.next_input(16)).unwrap();
            assert_eq!(ra.model, a);
            assert_eq!(ra.output.unwrap().len(), 12);
            assert!(fleet.groups()[0].shard_ids().contains(&ra.shard));
            let rb = fleet.infer_model(b, load.next_input(16)).unwrap();
            assert_eq!(rb.model, b);
            assert_eq!(rb.output.unwrap().len(), 10);
            assert_eq!(rb.shard, 2, "model-b traffic must stay on its own group");
        }
        let err = fleet.submit_to(ModelId(7), vec![0.0; 16]).err().unwrap();
        assert!(matches!(err, SubmitError::UnknownModel { .. }), "{err}");
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.groups, vec![("model-a".into(), vec![0, 1]), ("model-b".into(), vec![2])]);
        assert_eq!(m.shards[0].completed + m.shards[1].completed, 6);
        assert_eq!(m.shards[2].completed, 6);
    }

    #[test]
    fn catalog_fleet_serves_repeats_from_cache() {
        let cfg = ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 };
        let mut cat = ModelCatalog::new();
        let layers = synthetic_packed_network(&[16, 20, 12], 4, 4, 77).unwrap();
        cat.add_program(
            "cached",
            Arc::new(compile_packed_layers("cached", &layers, 0.2, 4, 4).unwrap()),
            cfg,
        )
        .unwrap();
        let reg = Arc::new(Registry::new());
        let fleet = Fleet::start_catalog(
            FleetConfig {
                shards: 0,
                batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
                queue_cap: 1024,
                metrics: Arc::clone(&reg),
                cache_entries: 32,
                ..FleetConfig::default()
            },
            Arc::new(cat),
            &[1],
        )
        .unwrap();
        let mut load = SyntheticLoad::new(1000.0, 5);
        let input = load.next_input(16);
        let cold = fleet.infer(input.clone()).unwrap();
        assert!(!cold.cached, "first submission must ride the engine path");
        let want = cold.output.unwrap();
        let hot = fleet.infer(input.clone()).unwrap();
        assert!(hot.cached);
        assert_eq!(hot.shard, CACHE_SHARD);
        assert_eq!(hot.batch_size, 0);
        let got = hot.output.unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "hit must be the stored output verbatim");
        }
        // NaN bypasses the cache but is still served by the engine.
        let nan = fleet.infer(vec![f32::NAN; 16]).unwrap();
        assert!(!nan.cached && nan.output.is_ok());
        assert_eq!(reg.counter_total("apu_fleet_cache_hits_total"), 1);
        assert_eq!(reg.counter_total("apu_fleet_cache_misses_total"), 1);
        assert_eq!(reg.counter_total("apu_fleet_cache_bypass_total"), 1);
        // Accounting rule: only the two engine-path requests enqueued.
        assert_eq!(reg.counter_total("apu_fleet_enqueued_total"), 2);
        let m = fleet.shutdown().unwrap();
        assert_eq!(m.cache.len(), 1);
        let stats = m.cache[0].as_ref().unwrap();
        assert_eq!((stats.hits, stats.misses, stats.bypass), (1, 1, 1));
        assert_eq!(stats.entries, 1);
    }

    #[test]
    fn counters_return_to_zero_when_drained() {
        let fleet =
            Fleet::start(config(2, DispatchPolicy::JoinShortestQueue, 64), |_| test_engine(3)).unwrap();
        let mut load = SyntheticLoad::new(1000.0, 17);
        let rxs: Vec<_> = (0..16).map(|_| fleet.submit(load.next_input(16)).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        // Every reply has been received, so nothing is queued/outstanding.
        for l in fleet.shard_loads() {
            assert_eq!(l.queued, 0);
            assert_eq!(l.outstanding, 0);
        }
        fleet.shutdown().unwrap();
    }
}
