//! SLO accounting: turn a fleet run's raw metrics into per-shard,
//! per-model, and fleet-wide latency percentiles, queue-depth, and
//! rejection-rate summaries — the numbers a production serving fleet is
//! actually held to (p50/p95/p99 targets, bounded rejection rate).
//! Multi-model fleets get one aggregate row per model group (latency
//! streams merged across the group's shards, so percentiles are exact),
//! alongside the per-shard rows and the fleet total.

use std::time::Duration;

use super::cache::CacheStats;
use super::fleet::FleetMetrics;
use super::server::ServerMetrics;
use crate::obs::metrics::Registry;
use crate::util::table::Table;

/// One row of SLO numbers (a shard, or the whole fleet).
#[derive(Debug, Clone)]
pub struct SloSnapshot {
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub mean_batch: f64,
    pub mean_queue_depth: f64,
    pub max_queue_depth: f64,
}

impl SloSnapshot {
    fn from_shard(m: &ServerMetrics) -> SloSnapshot {
        let mut lat = m.latency_us.clone();
        SloSnapshot {
            completed: m.completed,
            failed: m.failed,
            rejected: m.rejected,
            p50_us: lat.p50(),
            p95_us: lat.p95(),
            p99_us: lat.p99(),
            mean_us: lat.mean(),
            mean_batch: m.batch_sizes.mean(),
            mean_queue_depth: m.queue_depth.mean(),
            max_queue_depth: if m.queue_depth.count() == 0 { 0.0 } else { m.queue_depth.max() },
        }
    }

    /// Aggregate several shards' metrics into one row (a model group, or
    /// the whole fleet): latency/batch/depth streams are merged, so the
    /// percentiles are exact rather than averaged across shards.
    fn aggregate(shards: &[&ServerMetrics]) -> SloSnapshot {
        let mut lat = crate::util::stats::Summary::new();
        let mut batch = crate::util::stats::Summary::new();
        let mut depth = crate::util::stats::Summary::new();
        let (mut completed, mut failed, mut rejected) = (0u64, 0u64, 0u64);
        for s in shards {
            lat.merge(&s.latency_us);
            batch.merge(&s.batch_sizes);
            depth.merge(&s.queue_depth);
            completed += s.completed;
            failed += s.failed;
            rejected += s.rejected;
        }
        SloSnapshot {
            completed,
            failed,
            rejected,
            p50_us: lat.p50(),
            p95_us: lat.p95(),
            p99_us: lat.p99(),
            mean_us: lat.mean(),
            mean_batch: batch.mean(),
            mean_queue_depth: depth.mean(),
            max_queue_depth: if depth.count() == 0 { 0.0 } else { depth.max() },
        }
    }

    /// Fraction of arrivals (admitted + rejected) that were rejected.
    pub fn rejection_rate(&self) -> f64 {
        let arrivals = self.completed + self.failed + self.rejected;
        if arrivals == 0 {
            0.0
        } else {
            self.rejected as f64 / arrivals as f64
        }
    }
}

/// The full report: one snapshot per shard plus the fleet aggregate
/// (latency streams merged, so fleet percentiles are exact).
#[derive(Debug)]
pub struct SloReport {
    pub policy: &'static str,
    pub per_shard: Vec<SloSnapshot>,
    /// One aggregate row per model group, in model-id order.
    /// Single-model fleets have one `"default"` entry equal to the
    /// fleet row.
    pub per_model: Vec<(String, SloSnapshot)>,
    /// `(model label, global shard ids)` — which shards served which
    /// model (used to label per-shard rows and exported series).
    pub groups: Vec<(String, Vec<usize>)>,
    pub fleet: SloSnapshot,
    pub dead: Vec<(usize, String)>,
    pub elapsed: Duration,
    pub throughput_rps: f64,
    /// `(model label, final cache counters)` for every group that served
    /// with a result cache; empty for uncached fleets. Cache hits are
    /// *not* part of any latency/throughput row above — they never touch
    /// the engine path (the accounting rule in `coordinator::cache`).
    pub cache: Vec<(String, CacheStats)>,
}

impl SloReport {
    pub fn from_metrics(m: &FleetMetrics, elapsed: Duration) -> SloReport {
        let per_shard: Vec<SloSnapshot> = m.shards.iter().map(SloSnapshot::from_shard).collect();
        let per_model: Vec<(String, SloSnapshot)> = m
            .groups
            .iter()
            .map(|(name, ids)| {
                let ms: Vec<&ServerMetrics> =
                    ids.iter().filter_map(|&i| m.shards.get(i)).collect();
                (name.clone(), SloSnapshot::aggregate(&ms))
            })
            .collect();
        let fleet = SloSnapshot::aggregate(&m.shards.iter().collect::<Vec<_>>());
        let cache = m
            .groups
            .iter()
            .enumerate()
            .filter_map(|(i, (name, _))| {
                m.cache.get(i).cloned().flatten().map(|s| (name.clone(), s))
            })
            .collect();
        SloReport {
            policy: m.policy.name(),
            per_shard,
            per_model,
            groups: m.groups.clone(),
            fleet,
            dead: m.dead.clone(),
            elapsed,
            throughput_rps: m.throughput_rps(elapsed),
            cache,
        }
    }

    /// The model label a shard served under (`"default"` when the fleet
    /// predates model groups or the shard is unknown).
    fn model_of(&self, shard: usize) -> &str {
        self.groups
            .iter()
            .find(|(_, ids)| ids.contains(&shard))
            .map(|(name, _)| name.as_str())
            .unwrap_or("default")
    }

    /// Export the report as `apu_slo_*` gauges (one series per shard
    /// labelled with its model, one aggregate series per model, plus a
    /// `shard="fleet"` total) so percentiles and rejection rates ride
    /// the same registry dump as the live shard counters. Rows with no
    /// completed requests are skipped — their percentiles are
    /// undefined, and a NaN gauge would poison the Prometheus
    /// exposition.
    pub fn export(&self, reg: &Registry) {
        let mut rows: Vec<(Vec<(String, String)>, &SloSnapshot)> = self
            .per_shard
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let labels = vec![
                    ("model".to_string(), self.model_of(i).to_string()),
                    ("shard".to_string(), i.to_string()),
                ];
                (labels, s)
            })
            .collect();
        for (name, s) in &self.per_model {
            rows.push((vec![("model".to_string(), name.clone())], s));
        }
        rows.push((vec![("shard".to_string(), "fleet".to_string())], &self.fleet));
        for (labels, s) in rows {
            if s.completed == 0 {
                continue;
            }
            let l: Vec<(&str, &str)> =
                labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            for (name, help, v) in [
                ("apu_slo_p50_us", "latency p50 over the run, microseconds", s.p50_us),
                ("apu_slo_p95_us", "latency p95 over the run, microseconds", s.p95_us),
                ("apu_slo_p99_us", "latency p99 over the run, microseconds", s.p99_us),
                ("apu_slo_mean_us", "mean latency over the run, microseconds", s.mean_us),
                ("apu_slo_rejection_rate", "rejected / all arrivals", s.rejection_rate()),
            ] {
                if v.is_finite() {
                    reg.gauge(name, help, &l).set(v);
                }
            }
        }
        if self.throughput_rps.is_finite() {
            reg.gauge("apu_slo_throughput_rps", "completed requests per second", &[])
                .set(self.throughput_rps);
        }
        for (name, s) in &self.cache {
            // Skip models whose cache saw no cacheable traffic — a flat
            // 0 would read as "everything missed".
            if s.hits + s.misses == 0 {
                continue;
            }
            reg.gauge(
                "apu_slo_cache_hit_rate",
                "result-cache hits / (hits + misses) over the run",
                &[("model", name.as_str())],
            )
            .set(s.hit_rate());
        }
    }

    /// Render the per-shard + per-model + fleet tables (the `apu fleet`
    /// output). The per-model table only appears for multi-model fleets
    /// — for one model it would duplicate the fleet row.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "shard", "model", "done", "fail", "rej", "rej%", "p50us", "p95us", "p99us", "batch",
            "qdepth",
        ]);
        let row = |label: String, model: String, s: &SloSnapshot| -> Vec<String> {
            vec![
                label,
                model,
                s.completed.to_string(),
                s.failed.to_string(),
                s.rejected.to_string(),
                format!("{:.1}", 100.0 * s.rejection_rate()),
                format!("{:.0}", s.p50_us),
                format!("{:.0}", s.p95_us),
                format!("{:.0}", s.p99_us),
                format!("{:.2}", s.mean_batch),
                format!("{:.1}", s.mean_queue_depth),
            ]
        };
        for (i, s) in self.per_shard.iter().enumerate() {
            let model = self.model_of(i).to_string();
            if let Some((_, err)) = self.dead.iter().find(|(id, _)| *id == i) {
                t.row(&[
                    format!("{i}"),
                    model,
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("dead: {err}"),
                ]);
            } else {
                t.row(&row(format!("{i}"), model, s));
            }
        }
        t.row(&row("fleet".into(), "*".into(), &self.fleet));
        let mut out = format!(
            "policy={} shards={} models={} throughput={:.1} req/s elapsed={:.2}s\n{}",
            self.policy,
            self.per_shard.len(),
            self.per_model.len().max(1),
            self.throughput_rps,
            self.elapsed.as_secs_f64(),
            t.render()
        );
        if self.per_model.len() > 1 {
            let mut mt = Table::new(&[
                "model", "shards", "done", "fail", "rej", "rej%", "p50us", "p95us", "p99us",
            ]);
            for (name, s) in &self.per_model {
                let n_shards = self
                    .groups
                    .iter()
                    .find(|(g, _)| g == name)
                    .map(|(_, ids)| ids.len())
                    .unwrap_or(0);
                mt.row(&[
                    name.clone(),
                    n_shards.to_string(),
                    s.completed.to_string(),
                    s.failed.to_string(),
                    s.rejected.to_string(),
                    format!("{:.1}", 100.0 * s.rejection_rate()),
                    format!("{:.0}", s.p50_us),
                    format!("{:.0}", s.p95_us),
                    format!("{:.0}", s.p99_us),
                ]);
            }
            out.push_str("\nper-model:\n");
            out.push_str(&mt.render());
        }
        if !self.cache.is_empty() {
            let mut ct = Table::new(&[
                "model", "cap", "entries", "hits", "miss", "bypass", "evict", "hit%",
            ]);
            for (name, s) in &self.cache {
                ct.row(&[
                    name.clone(),
                    s.capacity.to_string(),
                    s.entries.to_string(),
                    s.hits.to_string(),
                    s.misses.to_string(),
                    s.bypass.to_string(),
                    s.evictions.to_string(),
                    format!("{:.1}", 100.0 * s.hit_rate()),
                ]);
            }
            out.push_str("\nresult cache (hits bypass the engine path entirely):\n");
            out.push_str(&ct.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dispatch::DispatchPolicy;

    fn shard_metrics(latencies: &[f64], failed: u64, rejected: u64) -> ServerMetrics {
        let mut m = ServerMetrics { failed, rejected, ..Default::default() };
        for &l in latencies {
            m.latency_us.add(l);
            m.completed += 1;
        }
        m.batch_sizes.add(latencies.len().max(1) as f64);
        m.queue_depth.add(latencies.len() as f64);
        m
    }

    #[test]
    fn fleet_percentiles_merge_shard_streams() {
        let a = shard_metrics(&[100.0, 200.0, 300.0], 0, 0);
        let b = shard_metrics(&[400.0, 500.0], 0, 0);
        let fm = FleetMetrics {
            shards: vec![a, b],
            dead: vec![],
            policy: DispatchPolicy::JoinShortestQueue,
            groups: vec![("default".into(), vec![0, 1])],
            cache: vec![],
        };
        let r = SloReport::from_metrics(&fm, Duration::from_secs(1));
        assert_eq!(r.fleet.completed, 5);
        // merged stream = [100..500]: p50 is the middle value
        assert!((r.fleet.p50_us - 300.0).abs() < 1e-9);
        assert!(r.fleet.p99_us <= 500.0 && r.fleet.p99_us > 490.0);
        assert_eq!(r.per_shard.len(), 2);
        assert!((r.throughput_rps - 5.0).abs() < 1e-9);
        // the single "default" group aggregates to the fleet row
        assert_eq!(r.per_model.len(), 1);
        assert_eq!(r.per_model[0].0, "default");
        assert_eq!(r.per_model[0].1.completed, 5);
        assert!((r.per_model[0].1.p50_us - r.fleet.p50_us).abs() < 1e-9);
    }

    #[test]
    fn per_model_rows_are_disjoint_group_aggregates() {
        let fm = FleetMetrics {
            shards: vec![
                shard_metrics(&[100.0, 200.0], 0, 0),
                shard_metrics(&[300.0, 400.0], 0, 0),
                shard_metrics(&[1000.0, 2000.0, 3000.0], 1, 2),
            ],
            dead: vec![],
            policy: DispatchPolicy::RoundRobin,
            groups: vec![("fast".into(), vec![0, 1]), ("slow".into(), vec![2])],
            cache: vec![],
        };
        let r = SloReport::from_metrics(&fm, Duration::from_secs(1));
        assert_eq!(r.per_model.len(), 2);
        let fast = &r.per_model[0].1;
        let slow = &r.per_model[1].1;
        assert_eq!(fast.completed, 4);
        assert_eq!(slow.completed, 3);
        assert_eq!(slow.failed, 1);
        assert_eq!(slow.rejected, 2);
        // fast merges shards 0+1 only: p50 of [100,200,300,400]
        assert!(fast.p50_us <= 300.0, "fast p50 {} polluted by slow group", fast.p50_us);
        assert!(slow.p50_us >= 1000.0, "slow p50 {} polluted by fast group", slow.p50_us);
        assert_eq!(fast.completed + slow.completed, r.fleet.completed);
        let out = r.render();
        assert!(out.contains("per-model:"), "{out}");
        assert!(out.contains("fast") && out.contains("slow"), "{out}");
    }

    #[test]
    fn rejection_rate_counts_all_arrivals() {
        let m = shard_metrics(&[50.0; 60], 20, 20);
        let fm = FleetMetrics {
            shards: vec![m],
            dead: vec![],
            policy: DispatchPolicy::RoundRobin,
            groups: vec![("default".into(), vec![0])],
            cache: vec![],
        };
        let r = SloReport::from_metrics(&fm, Duration::from_secs(1));
        // 60 completed + 20 failed + 20 rejected → 20% rejected
        assert!((r.fleet.rejection_rate() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn export_writes_gauges_and_skips_empty_shards() {
        let fm = FleetMetrics {
            shards: vec![shard_metrics(&[100.0, 200.0, 300.0], 0, 1), ServerMetrics::default()],
            dead: vec![],
            policy: DispatchPolicy::RoundRobin,
            groups: vec![("default".into(), vec![0, 1])],
            cache: vec![],
        };
        let r = SloReport::from_metrics(&fm, Duration::from_secs(1));
        let reg = Registry::new();
        r.export(&reg);
        let shard0: &[(&str, &str)] = &[("model", "default"), ("shard", "0")];
        let p50 = reg.gauge_value("apu_slo_p50_us", shard0).unwrap();
        assert!((p50 - 200.0).abs() < 1e-9);
        assert!(reg.gauge_value("apu_slo_p50_us", &[("shard", "fleet")]).is_some());
        // one aggregate series per model, labelled by model alone
        assert!(reg.gauge_value("apu_slo_p50_us", &[("model", "default")]).is_some());
        // the idle shard has no latency stream → no series for it
        assert!(reg
            .gauge_value("apu_slo_p50_us", &[("model", "default"), ("shard", "1")])
            .is_none());
        assert!(reg.gauge_value("apu_slo_throughput_rps", &[]).unwrap() > 0.0);
        let rate = reg.gauge_value("apu_slo_rejection_rate", shard0).unwrap();
        assert!((rate - 0.25).abs() < 1e-9);
    }

    #[test]
    fn cache_rows_render_and_export_hit_rate() {
        let fm = FleetMetrics {
            shards: vec![shard_metrics(&[100.0, 200.0], 0, 0), shard_metrics(&[300.0], 0, 0)],
            dead: vec![],
            policy: DispatchPolicy::JoinShortestQueue,
            groups: vec![("hot".into(), vec![0]), ("coldonly".into(), vec![1])],
            cache: vec![
                Some(CacheStats {
                    hits: 30,
                    misses: 10,
                    evictions: 2,
                    bypass: 1,
                    entries: 8,
                    capacity: 16,
                }),
                // cached group that saw no cacheable traffic: rendered,
                // but no hit-rate gauge (it would read as "all missed")
                Some(CacheStats { capacity: 4, ..CacheStats::default() }),
            ],
        };
        let r = SloReport::from_metrics(&fm, Duration::from_secs(1));
        assert_eq!(r.cache.len(), 2);
        let out = r.render();
        assert!(out.contains("result cache"), "{out}");
        assert!(out.contains("75.0"), "hit rate missing: {out}");
        let reg = Registry::new();
        r.export(&reg);
        let rate = reg.gauge_value("apu_slo_cache_hit_rate", &[("model", "hot")]).unwrap();
        assert!((rate - 0.75).abs() < 1e-9);
        assert!(reg.gauge_value("apu_slo_cache_hit_rate", &[("model", "coldonly")]).is_none());
        // uncached fleets keep rendering without a cache table
        let bare = FleetMetrics {
            shards: vec![shard_metrics(&[10.0], 0, 0)],
            dead: vec![],
            policy: DispatchPolicy::RoundRobin,
            groups: vec![("default".into(), vec![0])],
            cache: vec![],
        };
        let out = SloReport::from_metrics(&bare, Duration::from_secs(1)).render();
        assert!(!out.contains("result cache"), "{out}");
    }

    #[test]
    fn render_marks_dead_shards() {
        let fm = FleetMetrics {
            shards: vec![shard_metrics(&[10.0], 0, 0), ServerMetrics::default()],
            dead: vec![(1, "no hardware".into())],
            policy: DispatchPolicy::LeastOutstanding,
            groups: vec![("default".into(), vec![0, 1])],
            cache: vec![],
        };
        let out = SloReport::from_metrics(&fm, Duration::from_millis(100)).render();
        assert!(out.contains("dead: no hardware"));
        assert!(out.contains("policy=least-outstanding"));
        assert!(out.contains("fleet"));
    }
}
