//! Request-level result cache keyed on (model fingerprint, machine key,
//! canonical quantized input).
//!
//! The cheapest inference is the one never run. Every compiled program
//! opens with a host `Quantize` (the ingress quantizer), so two analog
//! inputs that land on the same quantization grid are *provably* the
//! same request: planned execution is input-deterministic (the bitwise
//! invariant `integration_plan.rs` enforces), so a cache hit may return
//! the stored output verbatim. [`InputKeyer`] canonicalizes an f32 input
//! through the model's ingress [`Quantizer`] (via `fake_slice`, the same
//! routine the engine itself runs first) and keys the result together
//! with the program fingerprint and the machine geometry — the same
//! fields the plan cache keys on — so entries never cross models or
//! machine instances.
//!
//! Canonicalization rules:
//! - **NaN bypasses.** `Quantizer::fake` collapses NaN to `+0.0`, which
//!   would alias a poisoned input with a legitimate zero input. Any NaN
//!   anywhere in the input makes [`InputKeyer::key`] return `None`; the
//!   request rides the normal engine path and is never cached.
//! - **`-0.0` and `0.0` share a key.** Both quantize to code 0; the sign
//!   of zero dies at the first accumulation (every compiled network's
//!   outputs pass through a MAC reduction whose accumulator starts at
//!   `+0.0`, and IEEE `x + ±0.0 == x` for `x != -0.0`), so outputs are
//!   bitwise identical. The keyer normalizes each quantized element with
//!   `+ 0.0` before taking its bits.
//! - **No ingress quantizer → exact bits.** Programs without a leading
//!   `Quantize` are keyed on the raw input bits — trivially sound, just
//!   less collapsing.
//!
//! **Accounting rule** (asserted by `integration_cache.rs`): a hit
//! replies *before* admission control — it never touches a shard queue,
//! batcher, or engine, so it increments **none** of the per-shard
//! `apu_fleet_*` series (enqueued/completed/engine_calls/batch_size/
//! queue_depth/latency). Hits, misses, evictions, and bypasses are
//! counted only in the `apu_fleet_cache_*` series and the SLO cache
//! table. This keeps JSQ's queue-depth signal honest: cached traffic is
//! invisible to the dispatcher.
//!
//! The store itself is a sharded, bounded LRU: small capacities (≤ 64)
//! use a single shard with exact LRU order (deterministic eviction, the
//! testable contract); larger capacities split into up to 16 shards to
//! keep lock contention off the submit path, each shard LRU within its
//! slice of the capacity.

use std::collections::hash_map::DefaultHasher;
use std::collections::{BTreeMap, HashMap};
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex};

use crate::obs::metrics::{self, Counter, Histogram, Registry};
use crate::pruning::Quantizer;
use crate::sim::ApuConfig;

use super::catalog::ModelEntry;

/// A canonical cache key: program fingerprint, machine geometry, and the
/// input's post-quantization bit pattern. Two requests with equal keys
/// are guaranteed (by planned-run determinism) to produce bitwise-equal
/// outputs.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    fingerprint: u64,
    n_pes: usize,
    pe_sram_bits: usize,
    clock_bits: u64,
    input: Vec<u32>,
}

/// Builds [`CacheKey`]s for one model: fingerprint + machine key fixed,
/// input canonicalized through the model's ingress quantizer.
#[derive(Debug, Clone)]
pub struct InputKeyer {
    fingerprint: u64,
    n_pes: usize,
    pe_sram_bits: usize,
    clock_bits: u64,
    quant: Option<Quantizer>,
}

impl InputKeyer {
    /// `quant` is the model's ingress quantizer when it has one; `None`
    /// falls back to exact-bits keying.
    pub fn new(fingerprint: u64, machine: &ApuConfig, quant: Option<Quantizer>) -> InputKeyer {
        InputKeyer {
            fingerprint,
            n_pes: machine.n_pes,
            pe_sram_bits: machine.pe_sram_bits,
            clock_bits: machine.clock_ghz.to_bits(),
            quant,
        }
    }

    /// The keyer for a catalog entry: its fingerprint, its machine, and
    /// the ingress quantizer recovered from its plan (or program).
    pub fn for_entry(entry: &ModelEntry) -> InputKeyer {
        InputKeyer::new(entry.fingerprint, &entry.machine, entry.input_quantizer())
    }

    /// Canonicalize `input` into a key, or `None` when the input must
    /// bypass the cache (any NaN element — see the module rules).
    pub fn key(&self, input: &[f32]) -> Option<CacheKey> {
        if input.iter().any(|v| v.is_nan()) {
            return None;
        }
        let words: Vec<u32> = match &self.quant {
            Some(q) => {
                let mut canon = input.to_vec();
                q.fake_slice(&mut canon);
                // `+ 0.0` folds -0.0 onto +0.0: both carry code 0.
                canon.iter().map(|v| (v + 0.0).to_bits()).collect()
            }
            None => input.iter().map(|v| v.to_bits()).collect(),
        };
        Some(CacheKey {
            fingerprint: self.fingerprint,
            n_pes: self.n_pes,
            pe_sram_bits: self.pe_sram_bits,
            clock_bits: self.clock_bits,
            input: words,
        })
    }
}

struct Slot {
    output: Vec<f32>,
    /// The shard tick at last touch; doubles as the LRU map key.
    tick: u64,
}

struct LruShard {
    cap: usize,
    map: HashMap<Arc<CacheKey>, Slot>,
    /// tick → key, ascending: the first entry is the least recently used.
    lru: BTreeMap<u64, Arc<CacheKey>>,
    tick: u64,
}

impl LruShard {
    fn touch(&mut self, old: u64, tick: u64) {
        let k = self.lru.remove(&old).expect("cache lru out of sync");
        self.lru.insert(tick, k);
    }
}

/// Sharded, bounded LRU store. `capacity` is the total entry bound; the
/// shard caps partition it exactly. Capacities ≤ 64 are single-sharded
/// (exact global LRU, deterministic eviction order).
pub struct ResultCache {
    shards: Vec<Mutex<LruShard>>,
    capacity: usize,
}

impl ResultCache {
    pub fn new(capacity: usize) -> ResultCache {
        let n = (capacity / 64).clamp(1, 16);
        let shards = (0..n)
            .map(|i| {
                let cap = capacity / n + usize::from(i < capacity % n);
                Mutex::new(LruShard { cap, map: HashMap::new(), lru: BTreeMap::new(), tick: 0 })
            })
            .collect();
        ResultCache { shards, capacity }
    }

    fn shard_of(&self, key: &CacheKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        h.finish() as usize % self.shards.len()
    }

    /// Look up a key, refreshing its LRU position on a hit. The stored
    /// output is returned by clone — it is the verbatim engine reply.
    pub fn get(&self, key: &CacheKey) -> Option<Vec<f32>> {
        let mut s = self.shards[self.shard_of(key)].lock().unwrap();
        s.tick += 1;
        let tick = s.tick;
        let (old, out) = {
            let slot = s.map.get_mut(key)?;
            let old = slot.tick;
            slot.tick = tick;
            (old, slot.output.clone())
        };
        s.touch(old, tick);
        Some(out)
    }

    /// Insert (or refresh) an entry, evicting least-recently-used ones
    /// as needed to stay within the shard's capacity slice. Returns the
    /// number of evictions. Re-inserting a present key only bumps its
    /// recency: by determinism the stored output already equals `output`.
    pub fn put(&self, key: CacheKey, output: Vec<f32>) -> u64 {
        if self.capacity == 0 {
            return 0;
        }
        let mut s = self.shards[self.shard_of(&key)].lock().unwrap();
        s.tick += 1;
        let tick = s.tick;
        if let Some(slot) = s.map.get_mut(&key) {
            let old = slot.tick;
            slot.tick = tick;
            s.touch(old, tick);
            return 0;
        }
        let mut evicted = 0u64;
        while s.map.len() >= s.cap {
            let (&oldest, _) = s.lru.iter().next().expect("full shard has an lru entry");
            let k = s.lru.remove(&oldest).unwrap();
            s.map.remove(&k);
            evicted += 1;
        }
        let k = Arc::new(key);
        s.lru.insert(tick, Arc::clone(&k));
        s.map.insert(k, Slot { output, tick });
        evicted
    }

    /// Entries currently resident (across all shards).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The total entry bound this cache was built with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// A point-in-time view of one model's cache counters, folded into
/// [`FleetMetrics`](super::fleet::FleetMetrics) and the SLO report.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    /// Requests answered from the cache (no engine involvement).
    pub hits: u64,
    /// Cacheable requests that took the engine path (and populated).
    pub misses: u64,
    /// Entries dropped to stay within capacity.
    pub evictions: u64,
    /// Requests that skipped the cache entirely (NaN input).
    pub bypass: u64,
    /// Entries resident when the snapshot was taken.
    pub entries: usize,
    /// The configured entry bound.
    pub capacity: usize,
}

impl CacheStats {
    /// hits / (hits + misses); 0 when the cache saw no cacheable traffic.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One model group's cache: the keyer, the store, and the registry
/// instruments (labelled by model, like every other fleet series).
pub(super) struct GroupCache {
    pub(super) keyer: InputKeyer,
    pub(super) store: Arc<ResultCache>,
    pub(super) hits: Counter,
    pub(super) misses: Counter,
    pub(super) evictions: Counter,
    pub(super) bypass: Counter,
    pub(super) hit_latency_us: Histogram,
}

impl GroupCache {
    pub(super) fn register(
        reg: &Registry,
        model: &str,
        keyer: InputKeyer,
        capacity: usize,
    ) -> GroupCache {
        let l: &[(&str, &str)] = &[("model", model)];
        GroupCache {
            keyer,
            store: Arc::new(ResultCache::new(capacity)),
            hits: reg.counter(
                "apu_fleet_cache_hits_total",
                "requests answered from the result cache (no engine call)",
                l,
            ),
            misses: reg.counter(
                "apu_fleet_cache_misses_total",
                "cacheable requests that took the engine path",
                l,
            ),
            evictions: reg.counter(
                "apu_fleet_cache_evictions_total",
                "cache entries dropped to stay within capacity",
                l,
            ),
            bypass: reg.counter(
                "apu_fleet_cache_bypass_total",
                "requests that skipped the cache (NaN input)",
                l,
            ),
            hit_latency_us: reg.histogram(
                "apu_fleet_cache_hit_latency_us",
                "submit-to-reply latency of cache hits, microseconds",
                &metrics::cache_latency_buckets_us(),
                l,
            ),
        }
    }

    /// Snapshot the instruments into a [`CacheStats`]. Counter handles
    /// read the registry series, so with a shared registry the figures
    /// span every fleet that used the same model label (the CLI runs one
    /// fleet per process; tests use private registries).
    pub(super) fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            evictions: self.evictions.get(),
            bypass: self.bypass.get(),
            entries: self.store.len(),
            capacity: self.store.capacity(),
        }
    }
}

/// Carried by a miss through the dispatch path: on a successful reply
/// the shard worker stores the output under the precomputed key.
pub(super) struct CacheFill {
    pub(super) store: Arc<ResultCache>,
    pub(super) key: CacheKey,
    pub(super) evictions: Counter,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> ApuConfig {
        ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 }
    }

    fn keyer(quant: Option<Quantizer>) -> InputKeyer {
        InputKeyer::new(0xfee1_600d, &machine(), quant)
    }

    #[test]
    fn negative_zero_and_zero_share_a_key() {
        let k = keyer(Some(Quantizer::new(4, 0.5)));
        let a = k.key(&[0.0, 1.0]).unwrap();
        let b = k.key(&[-0.0, 1.0]).unwrap();
        assert_eq!(a, b, "-0.0 and 0.0 both quantize to code 0");
    }

    #[test]
    fn nan_inputs_bypass_and_never_alias_zero() {
        let k = keyer(Some(Quantizer::new(4, 0.5)));
        // fake(NaN) == +0.0, so keying a NaN would poison the zero entry;
        // the keyer must refuse instead.
        assert!(k.key(&[f32::NAN, 1.0]).is_none());
        assert!(k.key(&[1.0, f32::NAN]).is_none());
        assert!(k.key(&[0.0, 1.0]).is_some());
    }

    #[test]
    fn same_codes_hash_to_the_same_key() {
        // scale 0.5: 0.10 and 0.12 both round to code 0; 0.30 to code 1.
        let k = keyer(Some(Quantizer::new(4, 0.5)));
        assert_eq!(k.key(&[0.10, 0.80]), k.key(&[0.12, 0.80]));
        assert_ne!(k.key(&[0.30, 0.80]), k.key(&[0.12, 0.80]));
    }

    #[test]
    fn fingerprint_machine_and_quantizer_separate_keys() {
        let q = Some(Quantizer::new(4, 0.5));
        let a = keyer(q).key(&[0.4]).unwrap();
        let other_model = InputKeyer::new(0xdead_beef, &machine(), q).key(&[0.4]).unwrap();
        assert_ne!(a, other_model);
        let other_machine = ApuConfig { n_pes: 9, ..machine() };
        let b = InputKeyer::new(0xfee1_600d, &other_machine, q).key(&[0.4]).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn no_quantizer_keys_exact_bits() {
        let k = keyer(None);
        // Without a grid to collapse onto, nearby floats stay distinct …
        assert_ne!(k.key(&[0.10]), k.key(&[0.12]));
        // … and so do the signed zeros (exact-bits fallback is sound for
        // any program, including ones that copy inputs straight through).
        assert_ne!(k.key(&[0.0]), k.key(&[-0.0]));
        assert!(k.key(&[f32::NAN]).is_none());
    }

    #[test]
    fn capacity_one_evicts_lru_deterministically() {
        let k = keyer(None);
        let c = ResultCache::new(1);
        let (a, b) = (k.key(&[1.0]).unwrap(), k.key(&[2.0]).unwrap());
        assert_eq!(c.put(a.clone(), vec![1.5]), 0);
        assert_eq!(c.get(&a).unwrap(), vec![1.5]);
        assert_eq!(c.put(b.clone(), vec![2.5]), 1, "second insert evicts the first");
        assert!(c.get(&a).is_none());
        assert_eq!(c.get(&b).unwrap(), vec![2.5]);
        assert_eq!(c.len(), 1);
        assert_eq!(c.capacity(), 1);
    }

    #[test]
    fn get_refreshes_lru_order() {
        let k = keyer(None);
        let c = ResultCache::new(2);
        let (a, b, d) =
            (k.key(&[1.0]).unwrap(), k.key(&[2.0]).unwrap(), k.key(&[3.0]).unwrap());
        c.put(a.clone(), vec![1.5]);
        c.put(b.clone(), vec![2.5]);
        // Touch `a`: now `b` is the LRU entry and must be the one evicted.
        assert!(c.get(&a).is_some());
        assert_eq!(c.put(d.clone(), vec![3.5]), 1);
        assert!(c.get(&b).is_none(), "b was least recently used");
        assert!(c.get(&a).is_some());
        assert!(c.get(&d).is_some());
    }

    #[test]
    fn reinserting_a_present_key_only_bumps_recency() {
        let k = keyer(None);
        let c = ResultCache::new(2);
        let (a, b, d) =
            (k.key(&[1.0]).unwrap(), k.key(&[2.0]).unwrap(), k.key(&[3.0]).unwrap());
        c.put(a.clone(), vec![1.5]);
        c.put(b.clone(), vec![2.5]);
        assert_eq!(c.put(a.clone(), vec![1.5]), 0, "refresh, not insert");
        assert_eq!(c.len(), 2);
        c.put(d, vec![3.5]);
        assert!(c.get(&b).is_none(), "refreshing a made b the LRU entry");
        assert!(c.get(&a).is_some());
    }

    #[test]
    fn large_capacity_shards_and_stays_bounded() {
        let k = keyer(None);
        let c = ResultCache::new(256);
        assert!(c.is_empty());
        let mut evicted = 0;
        for i in 0..1000 {
            evicted += c.put(k.key(&[i as f32]).unwrap(), vec![i as f32]);
        }
        assert!(c.len() <= 256, "resident {} exceeds capacity", c.len());
        assert_eq!(c.len() as u64 + evicted, 1000, "every insert is resident or evicted");
    }

    #[test]
    fn hit_rate_folds_hits_and_misses() {
        let s = CacheStats { hits: 3, misses: 1, ..CacheStats::default() };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }
}
