//! Deadline-aware dynamic batcher.
//!
//! Requests accumulate until either the batch is full or the oldest
//! request has waited `max_wait` — the standard latency/throughput dial
//! for edge serving (the accelerator itself is batch-1; batching
//! amortizes dispatch overhead and keeps the PJRT batch-8 artifact fed).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Why a batch was released (the batcher's two dials — observability
/// counts these per shard to show which dial a workload is riding).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// The batch filled to `max_batch`.
    Full,
    /// The oldest request waited out `max_wait`.
    Deadline,
}

/// A pending request in the queue.
#[derive(Debug)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// FIFO queue + release logic.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: VecDeque<Pending<T>>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        assert!(policy.max_batch > 0, "max_batch must be positive");
        Batcher { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, payload: T) {
        self.queue.push_back(Pending { payload, enqueued: Instant::now() });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be released `now`?
    pub fn ready(&self, now: Instant) -> bool {
        self.flush_reason(now).is_some()
    }

    /// Why a batch would be released `now` (`None`: not ready yet).
    pub fn flush_reason(&self, now: Instant) -> Option<FlushReason> {
        if self.queue.len() >= self.policy.max_batch {
            return Some(FlushReason::Full);
        }
        match self.queue.front() {
            Some(p) if now.duration_since(p.enqueued) >= self.policy.max_wait => {
                Some(FlushReason::Deadline)
            }
            _ => None,
        }
    }

    /// Pop up to `max_batch` requests (FIFO order preserved).
    pub fn take_batch(&mut self) -> Vec<Pending<T>> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).collect()
    }

    /// Time until the oldest request's deadline (None if empty).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            let waited = now.duration_since(p.enqueued);
            self.policy.max_wait.saturating_sub(waited)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn releases_on_full_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(60) });
        b.push(1);
        b.push(2);
        assert!(!b.ready(Instant::now()));
        b.push(3);
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        b.push("x");
        assert!(!b.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready(Instant::now()));
    }

    #[test]
    fn take_batch_caps_at_max() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) });
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.take_batch().len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn fifo_order_preserved_across_batches() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) });
        for i in 0..4 {
            b.push(i);
        }
        let first: Vec<i32> = b.take_batch().into_iter().map(|p| p.payload).collect();
        let second: Vec<i32> = b.take_batch().into_iter().map(|p| p.payload).collect();
        assert_eq!((first, second), (vec![0, 1], vec![2, 3]));
    }

    #[test]
    fn flush_reason_distinguishes_full_from_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) });
        assert_eq!(b.flush_reason(Instant::now()), None);
        b.push(1);
        assert_eq!(b.flush_reason(Instant::now()), None);
        b.push(2);
        assert_eq!(b.flush_reason(Instant::now()), Some(FlushReason::Full));
        b.take_batch();
        b.push(3);
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(b.flush_reason(Instant::now()), Some(FlushReason::Deadline));
    }

    #[test]
    fn deadline_counts_down() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) });
        assert!(b.next_deadline(Instant::now()).is_none());
        b.push(());
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(50));
    }
}
