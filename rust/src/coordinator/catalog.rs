//! Model catalog: the fleet's source of truth for *which* models are
//! being served and *how* each one executes.
//!
//! The serving stack used to be single-model end to end — one engine
//! factory, one program, every shard rebuilding its own execution plan.
//! A [`ModelCatalog`] turns that into model-keyed serving: each entry
//! names a model (resolved from a `zoo:<name>` spec or a compiled `.apu`
//! artifact path), and holds everything N shards need to serve it
//! without repeating work:
//!
//! * the compiled [`Program`] behind one shared [`Arc`],
//! * the machine model ([`ApuConfig`]) the program was mapped against
//!   (and that every shard's simulator must be sized to), and
//! * the shared [`ExecPlan`] resolved once through the process-wide
//!   plan cache ([`crate::sim::plan`]) — so a fleet of N shards serving
//!   the same model pays exactly one plan build, not N.
//!
//! [`ModelId`] is the request-routing handle: a dense index into the
//! catalog that [`super::fleet::Fleet::submit_to`] uses to pick the
//! target model's shard group.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::compiler::{pipeline, CostModel, PipelineOptions};
use crate::isa::{HostOpKind, Insn, Program};
use crate::pruning::Quantizer;
use crate::sim::{shared_plan, ApuConfig, ExecPlan};

/// Dense handle for a catalog model — what requests carry through the
/// fleet so the dispatcher can route them to the right shard group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ModelId(pub usize);

impl std::fmt::Display for ModelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "model#{}", self.0)
    }
}

/// One served model: its program, machine, and shared execution plan.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// Human-facing model name (the metrics/SLO label): the canonical
    /// zoo name, or the program name baked into an `.apu` artifact.
    pub name: String,
    /// The spec this entry was resolved from (`zoo:vgg-nano`,
    /// `prog.apu`, …) — kept for error messages and reports.
    pub spec: String,
    /// The compiled program, shared by every shard serving this model.
    pub program: Arc<Program>,
    /// The simulator machine the program was mapped against.
    pub machine: ApuConfig,
    /// Content fingerprint of `program` (the plan-cache key component).
    pub fingerprint: u64,
    /// Shared pre-built execution plan; `None` means the planner
    /// declined and shards run the reference interpreter.
    pub plan: Option<Arc<ExecPlan>>,
    /// Per-model result-cache capacity override: `None` inherits the
    /// fleet default ([`FleetConfig::cache_entries`]
    /// (super::fleet::FleetConfig::cache_entries)), `Some(0)` disables
    /// caching for this model, `Some(n)` bounds it to `n` entries.
    pub cache_entries: Option<usize>,
}

impl ModelEntry {
    /// The model's ingress quantizer — the host `Quantize` every
    /// compiled program opens with — recovered from the shared plan, or
    /// (for unplanned entries) by decoding the program's first
    /// instruction the way the planner would. `None` when the program
    /// does not start with a well-formed quantize; the result cache then
    /// falls back to exact-bits keying.
    pub fn input_quantizer(&self) -> Option<Quantizer> {
        if let Some(plan) = &self.plan {
            return plan.input_quantizer();
        }
        let Some(Insn::HostOp { op: HostOpKind::Quantize, seg }) = self.program.insns.first()
        else {
            return None;
        };
        let params = self.program.segment(*seg).ok()?.as_f32().ok()?;
        let scale = params.first().copied()?;
        let bits = params.get(1).map(|&b| b as u32).unwrap_or(4);
        if scale > 0.0 && scale.is_finite() && (2..=16).contains(&bits) {
            Some(Quantizer::new(bits, scale))
        } else {
            None
        }
    }
}

/// Named model entries resolved once, served by many shards.
#[derive(Debug, Clone, Default)]
pub struct ModelCatalog {
    entries: Vec<ModelEntry>,
}

impl ModelCatalog {
    pub fn new() -> ModelCatalog {
        ModelCatalog::default()
    }

    /// Resolve a comma-separated or pre-split list of model specs into a
    /// catalog (the `apu fleet --models a,b,c` entry point).
    pub fn from_specs<S: AsRef<str>>(specs: &[S], pes_override: Option<usize>) -> Result<ModelCatalog> {
        let mut cat = ModelCatalog::new();
        for s in specs {
            cat.add_spec(s.as_ref(), pes_override)?;
        }
        if cat.is_empty() {
            bail!("model catalog is empty (no specs given)");
        }
        Ok(cat)
    }

    /// Resolve one spec and append it:
    ///
    /// * `zoo:<name>` — compile the zoo network through the pipeline.
    ///   `-nano` networks map onto the nano instance, everything else
    ///   onto the paper geometry (the same rule `apu fleet --model`
    ///   always applied); `pes_override` resizes the PE array.
    /// * anything else — a path to a compiled `.apu` artifact
    ///   ([`Program::load`]); the machine defaults to the paper silicon
    ///   instance ([`ApuConfig::default`]) with `pes_override` applied.
    pub fn add_spec(&mut self, spec: &str, pes_override: Option<usize>) -> Result<ModelId> {
        if let Some(name) = spec.strip_prefix("zoo:") {
            let net = crate::nn::zoo::by_name(name).with_context(|| {
                format!(
                    "unknown zoo network {name} (available: {})",
                    crate::nn::zoo::names().join(", ")
                )
            })?;
            let mut machine = if net.name.ends_with("-nano") {
                CostModel::nano_4pe()
            } else {
                CostModel::paper_9pe()
            };
            if let Some(pes) = pes_override {
                machine.n_pes = pes;
            }
            let compiled = pipeline::compile_network(&net, &machine, &PipelineOptions::default())
                .with_context(|| format!("compiling {name} for the catalog"))?;
            let cfg = machine.apu_config();
            self.add_named(spec, &net.name, Arc::new(compiled.program), cfg)
        } else {
            let program = Program::load(spec)
                .with_context(|| format!("loading model artifact {spec} (specs are zoo:<name> or a .apu path)"))?;
            let mut cfg = ApuConfig::default();
            if let Some(pes) = pes_override {
                cfg.n_pes = pes;
            }
            let name = program.name.clone();
            self.add_named(spec, &name, Arc::new(program), cfg)
        }
    }

    /// Register an already-compiled program under `name` on `machine`
    /// (tests and benches build catalogs of synthetic programs this
    /// way). Resolves the shared plan through the process-wide cache.
    pub fn add_program(
        &mut self,
        name: &str,
        program: Arc<Program>,
        machine: ApuConfig,
    ) -> Result<ModelId> {
        self.add_named(name, name, program, machine)
    }

    fn add_named(
        &mut self,
        spec: &str,
        name: &str,
        program: Arc<Program>,
        machine: ApuConfig,
    ) -> Result<ModelId> {
        if self.id_of(name).is_some() {
            bail!("duplicate model name {name} in catalog (each entry must be unique)");
        }
        let fingerprint = program.fingerprint();
        // One plan build per (program, machine) process-wide; every
        // shard serving this entry loads the shared Arc.
        let plan = shared_plan(&program, &machine)
            .with_context(|| format!("resolving execution plan for {name}"))?;
        let id = ModelId(self.entries.len());
        self.entries.push(ModelEntry {
            name: name.to_string(),
            spec: spec.to_string(),
            program,
            machine,
            fingerprint,
            plan,
            cache_entries: None,
        });
        Ok(id)
    }

    /// Set (or clear) a model's result-cache capacity override — see
    /// [`ModelEntry::cache_entries`]. Takes effect on the next
    /// [`Fleet::start_catalog`](super::fleet::Fleet::start_catalog).
    pub fn set_cache_entries(&mut self, id: ModelId, entries: Option<usize>) -> Result<()> {
        let n = self.entries.len();
        let e = self
            .entries
            .get_mut(id.0)
            .with_context(|| format!("{id} out of range (catalog has {n} models)"))?;
        e.cache_entries = entries;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entry lookup; errors (not panics) on a stale/foreign id.
    pub fn get(&self, id: ModelId) -> Result<&ModelEntry> {
        self.entries
            .get(id.0)
            .with_context(|| format!("{id} out of range (catalog has {} models)", self.entries.len()))
    }

    pub fn id_of(&self, name: &str) -> Option<ModelId> {
        self.entries.iter().position(|e| e.name == name).map(ModelId)
    }

    pub fn iter(&self) -> impl Iterator<Item = (ModelId, &ModelEntry)> {
        self.entries.iter().enumerate().map(|(i, e)| (ModelId(i), e))
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Build a serving engine for `id`: a simulator sized to the entry's
    /// machine, loading the shared program + plan (no plan build, no
    /// program copy — the whole point of the catalog).
    pub fn engine(&self, id: ModelId) -> Result<super::engine::ApuEngine> {
        super::engine::ApuEngine::from_entry(self.get(id)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::emit::{compile_packed_layers, synthetic_packed_network};

    fn test_program(seed: u64, name: &str) -> Arc<Program> {
        let layers = synthetic_packed_network(&[16, 20, 12], 4, 4, seed).unwrap();
        Arc::new(compile_packed_layers(name, &layers, 0.2, 4, 4).unwrap())
    }

    fn test_cfg() -> ApuConfig {
        ApuConfig { n_pes: 4, pe_sram_bits: 1 << 16, clock_ghz: 1.0 }
    }

    #[test]
    fn catalog_resolves_zoo_specs_with_shared_plans() {
        let cat = ModelCatalog::from_specs(&["zoo:vgg-nano", "zoo:alexnet-nano"], None).unwrap();
        assert_eq!(cat.len(), 2);
        let vgg = cat.get(cat.id_of("vgg-nano").unwrap()).unwrap();
        let alex = cat.get(cat.id_of("alexnet-nano").unwrap()).unwrap();
        assert_ne!(vgg.fingerprint, alex.fingerprint);
        // compiled zoo networks are plannable — the shared plan must exist
        assert!(vgg.plan.is_some() && alex.plan.is_some());
        assert_eq!(vgg.plan.as_ref().unwrap().fingerprint(), vgg.fingerprint);
        // both engines serve their own dims
        let mut e = cat.engine(ModelId(0)).unwrap();
        use crate::coordinator::engine::Engine;
        let out = e.infer_batch(&[vec![0.1; e.input_dim()]]).unwrap();
        assert_eq!(out[0].len(), e.output_dim());
    }

    #[test]
    fn duplicate_names_and_bad_specs_error() {
        let mut cat = ModelCatalog::new();
        cat.add_program("m", test_program(3, "m"), test_cfg()).unwrap();
        assert!(cat.add_program("m", test_program(4, "m2"), test_cfg()).is_err());
        let err = format!("{:#}", cat.add_spec("zoo:nope", None).unwrap_err());
        assert!(err.contains("unknown zoo network") && err.contains("vgg-nano"), "{err}");
        assert!(cat.add_spec("/no/such/file.apu", None).is_err());
        let stale = format!("{:#}", cat.get(ModelId(9)).unwrap_err());
        assert!(stale.contains("out of range"), "{stale}");
    }

    #[test]
    fn entries_expose_ingress_quantizer_and_cache_override() {
        let mut cat = ModelCatalog::new();
        let id = cat.add_program("q", test_program(5, "q"), test_cfg()).unwrap();
        let e = cat.get(id).unwrap();
        // every compiled program opens with the ingress quantize
        let q = e.input_quantizer().expect("packed programs open with a quantize");
        assert!(q.scale > 0.0 && q.bits >= 2);
        assert_eq!(e.cache_entries, None, "entries inherit the fleet default");
        cat.set_cache_entries(id, Some(8)).unwrap();
        assert_eq!(cat.get(id).unwrap().cache_entries, Some(8));
        assert!(cat.set_cache_entries(ModelId(9), Some(1)).is_err());
    }

    #[test]
    fn artifact_spec_round_trips_through_catalog() {
        let program = test_program(11, "artifact-cat");
        let path = std::env::temp_dir().join(format!("apu-cat-{}.apu", std::process::id()));
        program.save(&path).unwrap();
        let mut cat = ModelCatalog::new();
        let id = cat.add_spec(path.to_str().unwrap(), Some(4)).unwrap();
        let _ = std::fs::remove_file(&path);
        let e = cat.get(id).unwrap();
        assert_eq!(e.name, "artifact-cat");
        assert_eq!(e.machine.n_pes, 4);
        assert_eq!(e.fingerprint, program.fingerprint());
    }
}
