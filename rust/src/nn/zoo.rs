//! The paper's evaluation networks, at shape level.
//!
//! Figs. 13–15 are cycle-count/speedup experiments: they need layer
//! geometry and sparsity structure, not trained weights, so the shape
//! library here is the faithful substrate (DESIGN.md §2).

use super::graph::{Layer, LayerKind, Network, Shape};

fn conv(name: &str, cout: usize, k: usize, stride: usize, groups: usize) -> Layer {
    Layer {
        name: name.into(),
        kind: LayerKind::Conv { cout, kh: k, kw: k, stride, groups, padding: k / 2 },
        relu: true,
    }
}

fn pool(name: &str) -> Layer {
    Layer { name: name.into(), kind: LayerKind::MaxPool { window: 2, stride: 2 }, relu: false }
}

fn fc(name: &str, dout: usize, relu: bool) -> Layer {
    Layer { name: name.into(), kind: LayerKind::Fc { dout }, relu }
}

/// LeNet-300-100 (Table 1 row 1; the e2e artifact model, input padded to
/// 800 so dims divide nb=10 — see python/compile/train.py).
pub fn lenet_300_100() -> Network {
    Network {
        name: "lenet-300-100".into(),
        input: Shape { h: 1, w: 1, c: 800 },
        layers: vec![fc("fc1", 300, true), fc("fc2", 100, true), fc("fc3", 10, false)],
    }
}

/// AlexNet (paper Table 1 / Fig. 15's FC6-8; conv2/4/5 are the original's
/// 2-group convolutions — the paper's §4.4.3-III example).
pub fn alexnet() -> Network {
    Network {
        name: "alexnet".into(),
        input: Shape { h: 227, w: 227, c: 3 },
        layers: vec![
            Layer {
                name: "conv1".into(),
                kind: LayerKind::Conv { cout: 96, kh: 11, kw: 11, stride: 4, groups: 1, padding: 0 },
                relu: true,
            },
            pool("pool1"),
            conv("conv2", 256, 5, 1, 2),
            pool("pool2"),
            conv("conv3", 384, 3, 1, 1),
            conv("conv4", 384, 3, 1, 2),
            conv("conv5", 256, 3, 1, 2),
            pool("pool5"),
            fc("fc6", 4096, true),
            fc("fc7", 4096, true),
            fc("fc8", 1000, false),
        ],
    }
}

/// Group degree that makes one group's unrolled kernel fit a 513-wide PE
/// (paper §4.4.3-III: "fitting even the largest of convolutions ... onto
/// just 9 513x513 PEs"): the smallest power of two `g` dividing both
/// channel counts with `k²·cin/g ≤ 513`.
fn fit_groups(k: usize, cin: usize, cout: usize) -> usize {
    let mut g = 1;
    while k * k * cin / g > 513 && g < cin && g < cout && cin % (g * 2) == 0 && cout % (g * 2) == 0 {
        g *= 2;
    }
    g
}

/// VGG-19 (Fig. 13): 16 convolutions in 5 stages + 3 FC layers.
/// `group_conv=true` replaces each conv with the structured-sparse group
/// convolution the accelerator executes (§4.4.3-III, Fig. 12).
pub fn vgg19(group_conv: bool) -> Network {
    let g = |cin: usize| if group_conv { fit_groups(3, cin, cin.max(64)) } else { 1 };
    let mut layers = Vec::new();
    let stages: &[(usize, usize)] = &[(2, 64), (2, 128), (4, 256), (4, 512), (4, 512)];
    let mut cin = 3;
    for (si, &(n, cout)) in stages.iter().enumerate() {
        for li in 0..n {
            // first conv of stage 1 has cin=3: never grouped
            let groups = if cin <= 3 { 1 } else { g(cin) };
            layers.push(conv(&format!("conv{}_{}", si + 1, li + 1), cout, 3, 1, groups));
            cin = cout;
        }
        layers.push(pool(&format!("pool{}", si + 1)));
    }
    layers.push(fc("fc6", 4096, true));
    layers.push(fc("fc7", 4096, true));
    layers.push(fc("fc8", 1000, false));
    Network { name: if group_conv { "vgg19-group".into() } else { "vgg19".into() }, input: Shape { h: 224, w: 224, c: 3 }, layers }
}

/// ResNet-50 (Fig. 14): bottleneck stages as conv shapes (projection
/// shortcuts included; batch-norms folded at compile time so omitted).
pub fn resnet50(group_conv: bool) -> Network {
    let mut layers = Vec::new();
    layers.push(Layer {
        name: "conv1".into(),
        kind: LayerKind::Conv { cout: 64, kh: 7, kw: 7, stride: 2, groups: 1, padding: 3 },
        relu: true,
    });
    layers.push(Layer { name: "pool1".into(), kind: LayerKind::MaxPool { window: 3, stride: 2 }, relu: false });
    // (blocks, mid, out, first-stride)
    let stages: &[(usize, usize, usize, usize)] =
        &[(3, 64, 256, 1), (4, 128, 512, 2), (6, 256, 1024, 2), (3, 512, 2048, 2)];
    let g = |c: usize| if group_conv { fit_groups(3, c, c) } else { 1 };
    for (si, &(blocks, mid, cout, stride0)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let stride = if b == 0 { stride0 } else { 1 };
            let p = format!("res{}_{}", si + 2, b + 1);
            layers.push(conv(&format!("{p}_1x1a"), mid, 1, stride, g(mid)));
            layers.push(conv(&format!("{p}_3x3"), mid, 3, 1, g(mid)));
            layers.push(conv(&format!("{p}_1x1b"), cout, 1, 1, g(mid)));
        }
    }
    layers.push(fc("fc", 1000, false));
    Network {
        name: if group_conv { "resnet50-group".into() } else { "resnet50".into() },
        input: Shape { h: 224, w: 224, c: 3 },
        layers,
    }
}

/// Reduced AlexNet: the same front-heavy topology (big first kernel, a
/// 2-group conv, an FC tail) scaled to a 16×16 input so that on
/// [`CostModel::nano_4pe`](crate::compiler::CostModel::nano_4pe) it
/// genuinely *tiles* (§4.4.3 case II) yet still simulates in
/// milliseconds:
///
/// * `conv1` — 7×7×3 kernel, 147 unrolled columns > the 128-wide PE →
///   `ConvLarge`, two column tiles folded on the host;
/// * `conv2` — 2-group conv, 144 columns per group → tiled `ConvGroup`;
/// * `fc1` — structured blocks of 16×256 → column-tiled FC;
/// * `fc2` — 10 outputs, indivisible by 4 blocks → dense untiled head.
///
/// Every tiled geometry divides the 4-PE machine evenly, so the emitted
/// wave structure matches the analytic model's compute-cycle count
/// exactly (the cross-validation tests assert it). The union of tile
/// weights exceeds the nano instance's PE SRAM residency, so the
/// program *streams* weights per run — the AlexNet-flavored version of
/// the paper's Fig. 15 folding dip.
pub fn alexnet_nano() -> Network {
    Network {
        name: "alexnet-nano".into(),
        input: Shape { h: 16, w: 16, c: 3 },
        layers: vec![
            Layer {
                name: "conv1".into(),
                kind: LayerKind::Conv { cout: 32, kh: 7, kw: 7, stride: 1, groups: 1, padding: 3 },
                relu: true,
            },
            pool("pool1"),
            conv("conv2", 64, 3, 1, 2),
            pool("pool2"),
            fc("fc1", 64, true),
            fc("fc2", 10, false),
        ],
    }
}

/// Reduced VGG: the same conv/pool/FC topology scaled to a 16×16 input so
/// the whole network lowers through `compiler::pipeline` into an
/// *executable* program (every conv is case I/III, every FC fits one PE)
/// and simulates in milliseconds — the end-to-end serving model for
/// fleet tests. Includes a batch-norm layer so the normalization passes
/// are exercised on the executable path (`conv2_1` carries no ReLU of its
/// own; `bn2`'s trailing ReLU fuses into it at compile time).
pub fn vgg_nano() -> Network {
    Network {
        name: "vgg-nano".into(),
        input: Shape { h: 16, w: 16, c: 3 },
        layers: vec![
            conv("conv1_1", 16, 3, 1, 1),
            conv("conv1_2", 16, 3, 1, 2),
            pool("pool1"),
            Layer {
                name: "conv2_1".into(),
                kind: LayerKind::Conv { cout: 32, kh: 3, kw: 3, stride: 1, groups: 2, padding: 1 },
                relu: false,
            },
            Layer { name: "bn2".into(), kind: LayerKind::BatchNorm, relu: true },
            pool("pool2"),
            fc("fc1", 64, true),
            fc("fc2", 10, false),
        ],
    }
}

/// CLI lookup: a zoo network by name (`apu compile --net <name>`).
pub fn by_name(name: &str) -> Option<Network> {
    Some(match name {
        // "lenet-5" is the spelling most serving configs use; it maps
        // to the same FC stack the paper evaluates.
        "lenet" | "lenet-300-100" | "lenet-5" => lenet_300_100(),
        "alexnet" => alexnet(),
        "alexnet-nano" | "alexnet_nano" => alexnet_nano(),
        "vgg19" | "vgg19-group" => vgg19(true),
        "vgg19-dense" => vgg19(false),
        "resnet50" | "resnet50-group" => resnet50(true),
        "resnet50-dense" => resnet50(false),
        "vgg-nano" | "vgg_nano" => vgg_nano(),
        "mha" => transformer_mha(8, 512, 64),
        _ => return None,
    })
}

/// The canonical CLI spellings [`by_name`] accepts — listed in
/// unknown-network errors so `apu compile --net typo` tells the user
/// what exists.
pub fn names() -> &'static [&'static str] {
    &[
        "lenet",
        "alexnet",
        "alexnet-nano",
        "vgg19",
        "vgg19-dense",
        "resnet50",
        "resnet50-dense",
        "vgg-nano",
        "mha",
    ]
}

/// One Transformer multi-head-attention layer (paper §4.4.4): each head's
/// projections map onto one PE.
pub fn transformer_mha(heads: usize, dmodel: usize, seq: usize) -> Network {
    Network {
        name: format!("mha-{heads}h-{dmodel}d"),
        input: Shape { h: 1, w: seq, c: dmodel },
        layers: vec![Layer {
            name: "mha".into(),
            kind: LayerKind::Attention { heads, dmodel, dk: dmodel / heads, seq },
            relu: false,
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg19_geometry() {
        let n = vgg19(false);
        let convs = n.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv { .. })).count();
        let fcs = n.layers.iter().filter(|l| matches!(l.kind, LayerKind::Fc { .. })).count();
        assert_eq!(convs, 16);
        assert_eq!(fcs, 3);
        let shapes = n.shapes().unwrap();
        assert_eq!(shapes.last().unwrap().flat(), 1000);
        // VGG-19 ≈ 19.6 GMACs, ~143.6M params (the canonical numbers)
        let gmacs = n.macs().unwrap().iter().sum::<u64>() as f64 / 1e9;
        assert!((gmacs - 19.6).abs() < 1.0, "gmacs {gmacs}");
        let mparams = n.params().unwrap().iter().sum::<u64>() as f64 / 1e6;
        assert!((mparams - 143.6).abs() < 3.0, "params {mparams}M");
    }

    #[test]
    fn vgg19_fc6_is_the_monster() {
        // Fig. 15's VGGFC6: 25088 → 4096 ≈ 102.8M params.
        let n = vgg19(false);
        let shapes = n.shapes().unwrap();
        let fc6_idx = n.layers.iter().position(|l| l.name == "fc6").unwrap();
        assert_eq!(shapes[fc6_idx].flat(), 25088);
        let p = n.params().unwrap()[fc6_idx];
        assert!((p as f64 / 1e6 - 102.8).abs() < 0.5, "fc6 params {p}");
    }

    #[test]
    fn resnet50_geometry() {
        let n = resnet50(false);
        let shapes = n.shapes().unwrap();
        assert_eq!(shapes.last().unwrap().flat(), 1000);
        // ResNet-50 ≈ 3.8-4.1 GMACs (without BN/shortcut adds)
        let gmacs = n.macs().unwrap().iter().sum::<u64>() as f64 / 1e9;
        assert!(gmacs > 3.0 && gmacs < 4.6, "gmacs {gmacs}");
    }

    #[test]
    fn group_conv_reduces_macs() {
        let dense: u64 = vgg19(false).macs().unwrap().iter().sum();
        let grouped: u64 = vgg19(true).macs().unwrap().iter().sum();
        // early 64-channel stages stay lightly grouped, so the whole-network
        // reduction is ~2.8× (per-layer reductions reach 8×).
        assert!(grouped < dense / 2, "grouping should slash MACs: {grouped} vs {dense}");
        // shapes unchanged
        assert_eq!(vgg19(true).shapes().unwrap(), vgg19(false).shapes().unwrap());
    }

    #[test]
    fn alexnet_fc_params_dominate() {
        // The §5 argument: FC layers own most parameters (~94% in AlexNet).
        let n = alexnet();
        let params = n.params().unwrap();
        let total: u64 = params.iter().sum();
        let fc: u64 = n
            .layers
            .iter()
            .zip(&params)
            .filter(|(l, _)| matches!(l.kind, LayerKind::Fc { .. }))
            .map(|(_, &p)| p)
            .sum();
        assert!(fc as f64 / total as f64 > 0.9);
    }

    #[test]
    fn lenet_dims() {
        let n = lenet_300_100();
        let p: u64 = n.params().unwrap().iter().sum();
        assert_eq!(p, (800 * 300 + 300 + 300 * 100 + 100 + 100 * 10 + 10) as u64);
    }

    #[test]
    fn mha_maps_heads() {
        let n = transformer_mha(8, 512, 64);
        assert!(n.macs().unwrap()[0] > 0);
    }

    #[test]
    fn vgg_nano_geometry() {
        let n = vgg_nano();
        let shapes = n.shapes().unwrap();
        assert_eq!(shapes.last().unwrap().flat(), 10);
        // fc1 input is the pooled 4x4x32 = 512 plane
        let fc1 = n.layers.iter().position(|l| l.name == "fc1").unwrap();
        assert_eq!(shapes[fc1].flat(), 512);
        // small enough to simulate: well under a million MACs
        let macs: u64 = n.macs().unwrap().iter().sum();
        assert!(macs < 1_000_000, "vgg-nano macs {macs}");
    }

    #[test]
    fn by_name_covers_the_zoo() {
        for name in ["lenet", "alexnet", "alexnet-nano", "vgg19", "resnet50", "vgg-nano", "mha"] {
            assert!(by_name(name).is_some(), "missing zoo entry {name}");
        }
        assert!(by_name("nope").is_none());
        // the error-listing helper stays in sync with the lookup
        for name in names() {
            assert!(by_name(name).is_some(), "names() lists unknown entry {name}");
        }
    }

    #[test]
    fn alexnet_nano_geometry() {
        let n = alexnet_nano();
        let shapes = n.shapes().unwrap();
        assert_eq!(shapes.last().unwrap().flat(), 10);
        // conv1's unrolled kernel exceeds the nano instance's 128-wide PE
        let model = crate::compiler::CostModel::nano_4pe();
        let d = crate::compiler::decide_layer(&model, &n.layers[0].kind, shapes[0], shapes[1]).unwrap();
        assert_eq!(d.case, crate::compiler::MappingCase::ConvLarge);
        assert!(!d.fits_one_pe(), "conv1 must tile across PEs ({}x{})", d.th, d.tw);
        // fc1 sees the pooled 4×4×64 = 1024 plane (two 128-wide column
        // tiles per 256-wide structured block)
        let fc1 = n.layers.iter().position(|l| l.name == "fc1").unwrap();
        assert_eq!(shapes[fc1].flat(), 1024);
        // small enough to simulate quickly
        let macs: u64 = n.macs().unwrap().iter().sum();
        assert!(macs < 3_000_000, "alexnet-nano macs {macs}");
    }
}
