//! Graph normalization passes — stage 1 of `compiler::pipeline`.
//!
//! The paper folds batch normalization into the preceding conv/FC layer at
//! compile time (§4.4.3 "Batch Normalization") and fuses trailing ReLUs
//! into the PE datapath. These passes rewrite the layer graph accordingly
//! and record *where* every original layer went, so the weight-level fold
//! (`compiler::pipeline::NetworkWeights::fold`) can apply the matching
//! numeric transform: `y = s·(Wx + b) + t  ⇒  W' = s·W, b' = s·b + t`.

use anyhow::{bail, Result};

use super::graph::{LayerKind, Network};

/// Where one original layer went during normalization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerFate {
    /// Survived; index into the normalized layer list.
    Kept(usize),
    /// Batch norm folded into the surviving layer at this normalized index.
    FoldedInto(usize),
}

/// A normalized network plus the provenance map for the numeric fold.
#[derive(Debug, Clone)]
pub struct Normalized {
    pub net: Network,
    /// `fates[i]` = what happened to original layer `i`.
    pub fates: Vec<LayerFate>,
}

impl Normalized {
    /// Original-layer indices that were folded away.
    pub fn folded(&self) -> Vec<usize> {
        self.fates
            .iter()
            .enumerate()
            .filter(|(_, f)| matches!(f, LayerFate::FoldedInto(_)))
            .map(|(i, _)| i)
            .collect()
    }
}

/// Fold every `BatchNorm` into its preceding conv/FC layer and fuse its
/// trailing-ReLU flag into the survivor (a `conv → bn(relu)` pair becomes
/// one conv with `relu = true`).
pub fn normalize(net: &Network) -> Result<Normalized> {
    net.shapes()?; // validate geometry before rewriting
    let mut layers = Vec::with_capacity(net.layers.len());
    let mut fates = Vec::with_capacity(net.layers.len());
    for l in &net.layers {
        match l.kind {
            LayerKind::BatchNorm => {
                let Some(prev_idx) = layers.len().checked_sub(1) else {
                    bail!("{}: batch norm has no preceding layer to fold into", l.name);
                };
                let prev: &mut super::graph::Layer = &mut layers[prev_idx];
                if !matches!(prev.kind, LayerKind::Conv { .. } | LayerKind::Fc { .. }) {
                    bail!("{}: batch norm must follow a conv/FC layer, found {}", l.name, prev.name);
                }
                if prev.relu {
                    // s·relu(Wx+b)+t ≠ relu(s·(Wx+b)+t): the affine fold
                    // is only valid on the producer's pre-activation.
                    bail!("{}: cannot fold batch norm through {}'s fused ReLU", l.name, prev.name);
                }
                prev.relu = l.relu;
                fates.push(LayerFate::FoldedInto(prev_idx));
            }
            _ => {
                layers.push(l.clone());
                fates.push(LayerFate::Kept(layers.len() - 1));
            }
        }
    }
    if layers.is_empty() {
        bail!("{}: network is empty after normalization", net.name);
    }
    Ok(Normalized {
        net: Network { name: net.name.clone(), input: net.input, layers },
        fates,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::graph::{Layer, Shape};

    fn bn_net() -> Network {
        Network {
            name: "bn".into(),
            input: Shape { h: 4, w: 4, c: 4 },
            layers: vec![
                Layer {
                    name: "conv".into(),
                    kind: LayerKind::Conv { cout: 8, kh: 3, kw: 3, stride: 1, groups: 1, padding: 1 },
                    relu: false,
                },
                Layer { name: "bn".into(), kind: LayerKind::BatchNorm, relu: true },
                Layer { name: "fc".into(), kind: LayerKind::Fc { dout: 10 }, relu: false },
            ],
        }
    }

    #[test]
    fn bn_folds_and_fuses_relu() {
        let n = normalize(&bn_net()).unwrap();
        assert_eq!(n.net.layers.len(), 2);
        assert_eq!(n.net.layers[0].name, "conv");
        assert!(n.net.layers[0].relu, "bn's trailing relu must fuse into the conv");
        assert_eq!(
            n.fates,
            vec![LayerFate::Kept(0), LayerFate::FoldedInto(0), LayerFate::Kept(1)]
        );
        assert_eq!(n.folded(), vec![1]);
        // shapes unchanged end to end (bn is shape-preserving)
        assert_eq!(
            n.net.shapes().unwrap().last().unwrap().flat(),
            bn_net().shapes().unwrap().last().unwrap().flat()
        );
    }

    #[test]
    fn leading_bn_rejected() {
        let net = Network {
            name: "bad".into(),
            input: Shape { h: 1, w: 1, c: 8 },
            layers: vec![Layer { name: "bn0".into(), kind: LayerKind::BatchNorm, relu: false }],
        };
        assert!(normalize(&net).is_err());
    }

    #[test]
    fn bn_after_fused_relu_rejected() {
        // relu-then-bn cannot fold: s·relu(y)+t ≠ relu(s·y+t).
        let mut net = bn_net();
        net.layers[0].relu = true;
        assert!(normalize(&net).is_err());
    }

    #[test]
    fn bn_after_pool_rejected() {
        let net = Network {
            name: "bad".into(),
            input: Shape { h: 4, w: 4, c: 4 },
            layers: vec![
                Layer { name: "p".into(), kind: LayerKind::MaxPool { window: 2, stride: 2 }, relu: false },
                Layer { name: "bn".into(), kind: LayerKind::BatchNorm, relu: false },
            ],
        };
        assert!(normalize(&net).is_err());
    }

    #[test]
    fn bn_free_networks_pass_through() {
        let net = crate::nn::zoo::lenet_300_100();
        let n = normalize(&net).unwrap();
        assert_eq!(n.net, net);
        assert!(n.folded().is_empty());
    }
}
