//! Abstract network graph: the compiler's input IR.

use anyhow::{bail, Result};

/// Spatial activation shape flowing between layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Shape {
    pub fn flat(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// Layer kinds the APU framework maps (paper §4.4.3–4.4.4).
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Fully connected `din → dout`.
    Fc { dout: usize },
    /// 2D convolution, `groups`-way group conv (`groups == 1` = standard).
    Conv { cout: usize, kh: usize, kw: usize, stride: usize, groups: usize, padding: usize },
    /// Square max-pool.
    MaxPool { window: usize, stride: usize },
    /// Batch normalization (folded into the preceding conv/FC at compile
    /// time — paper §4.4.3 "Batch Normalization").
    BatchNorm,
    /// Multi-head self-attention (paper §4.4.4): `heads` heads over model
    /// dim `dmodel`, head dim `dk`, sequence length `seq`.
    Attention { heads: usize, dmodel: usize, dk: usize, seq: usize },
}

/// A named layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Whether a ReLU follows (fused into the PE datapath).
    pub relu: bool,
}

/// A network: input shape plus a layer list.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub name: String,
    pub input: Shape,
    pub layers: Vec<Layer>,
}

impl Network {
    /// Propagate shapes; errors on inconsistent geometry.
    pub fn shapes(&self) -> Result<Vec<Shape>> {
        let mut shapes = vec![self.input];
        let mut cur = self.input;
        for l in &self.layers {
            cur = match &l.kind {
                LayerKind::Fc { dout } => Shape { h: 1, w: 1, c: *dout },
                LayerKind::Conv { cout, kh, kw, stride, groups, padding } => {
                    if cur.c % groups != 0 || cout % groups != 0 {
                        bail!("{}: groups {} do not divide channels {}→{}", l.name, groups, cur.c, cout);
                    }
                    let oh = (cur.h + 2 * padding).saturating_sub(*kh) / stride + 1;
                    let ow = (cur.w + 2 * padding).saturating_sub(*kw) / stride + 1;
                    if oh == 0 || ow == 0 {
                        bail!("{}: kernel {}x{} larger than input {}x{}", l.name, kh, kw, cur.h, cur.w);
                    }
                    Shape { h: oh, w: ow, c: *cout }
                }
                LayerKind::MaxPool { window, stride } => {
                    let oh = cur.h.saturating_sub(*window) / stride + 1;
                    let ow = cur.w.saturating_sub(*window) / stride + 1;
                    if oh == 0 || ow == 0 {
                        bail!("{}: pool window too large", l.name);
                    }
                    Shape { h: oh, w: ow, c: cur.c }
                }
                LayerKind::BatchNorm => cur,
                LayerKind::Attention { dmodel, seq, .. } => Shape { h: 1, w: *seq, c: *dmodel },
            };
            shapes.push(cur);
        }
        Ok(shapes)
    }

    /// Multiply-accumulate count per layer (inference, batch 1).
    pub fn macs(&self) -> Result<Vec<u64>> {
        let shapes = self.shapes()?;
        let mut out = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            let (inp, outp) = (shapes[i], shapes[i + 1]);
            let m = match &l.kind {
                LayerKind::Fc { dout } => (inp.flat() * dout) as u64,
                LayerKind::Conv { cout, kh, kw, groups, .. } => {
                    (outp.h * outp.w) as u64 * (*cout as u64) * (kh * kw) as u64 * (inp.c / groups) as u64
                }
                LayerKind::MaxPool { .. } | LayerKind::BatchNorm => 0,
                LayerKind::Attention { heads, dmodel, dk, seq } => {
                    // Q/K/V/O projections + QK^T + AV per head.
                    let proj = 4 * seq * dmodel * (heads * dk);
                    let attn = 2 * heads * seq * seq * dk;
                    (proj + attn) as u64
                }
            };
            out.push(m);
        }
        Ok(out)
    }

    /// Parameter count per layer.
    pub fn params(&self) -> Result<Vec<u64>> {
        let shapes = self.shapes()?;
        let mut out = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            let inp = shapes[i];
            let p = match &l.kind {
                LayerKind::Fc { dout } => (inp.flat() * dout + dout) as u64,
                LayerKind::Conv { cout, kh, kw, groups, .. } => {
                    (cout * kh * kw * (inp.c / groups) + cout) as u64
                }
                LayerKind::MaxPool { .. } => 0,
                LayerKind::BatchNorm => 2 * inp.c as u64,
                LayerKind::Attention { heads, dmodel, dk, .. } => (4 * dmodel * heads * dk) as u64,
            };
            out.push(p);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        Network {
            name: "tiny".into(),
            input: Shape { h: 8, w: 8, c: 3 },
            layers: vec![
                Layer {
                    name: "conv1".into(),
                    kind: LayerKind::Conv { cout: 16, kh: 3, kw: 3, stride: 1, groups: 1, padding: 1 },
                    relu: true,
                },
                Layer { name: "pool1".into(), kind: LayerKind::MaxPool { window: 2, stride: 2 }, relu: false },
                Layer { name: "bn1".into(), kind: LayerKind::BatchNorm, relu: false },
                Layer { name: "fc1".into(), kind: LayerKind::Fc { dout: 10 }, relu: false },
            ],
        }
    }

    #[test]
    fn shape_propagation() {
        let s = tiny().shapes().unwrap();
        assert_eq!(s[1], Shape { h: 8, w: 8, c: 16 }); // same-padded conv
        assert_eq!(s[2], Shape { h: 4, w: 4, c: 16 }); // pooled
        assert_eq!(s[3], Shape { h: 4, w: 4, c: 16 }); // bn passthrough
        assert_eq!(s[4], Shape { h: 1, w: 1, c: 10 });
    }

    #[test]
    fn mac_counts() {
        let m = tiny().macs().unwrap();
        assert_eq!(m[0], 8 * 8 * 16 * 9 * 3);
        assert_eq!(m[1], 0);
        assert_eq!(m[3], (4 * 4 * 16 * 10) as u64);
    }

    #[test]
    fn group_conv_divides_macs() {
        let mk = |groups| Network {
            name: "g".into(),
            input: Shape { h: 4, w: 4, c: 8 },
            layers: vec![Layer {
                name: "c".into(),
                kind: LayerKind::Conv { cout: 8, kh: 3, kw: 3, stride: 1, groups, padding: 1 },
                relu: true,
            }],
        };
        let m1 = mk(1).macs().unwrap()[0];
        let m4 = mk(4).macs().unwrap()[0];
        assert_eq!(m1, 4 * m4); // group conv cuts MACs by the group count
    }

    #[test]
    fn rejects_bad_groups() {
        let n = Network {
            name: "bad".into(),
            input: Shape { h: 4, w: 4, c: 6 },
            layers: vec![Layer {
                name: "c".into(),
                kind: LayerKind::Conv { cout: 8, kh: 3, kw: 3, stride: 1, groups: 4, padding: 1 },
                relu: true,
            }],
        };
        assert!(n.shapes().is_err());
    }

    #[test]
    fn attention_macs_positive() {
        let n = Network {
            name: "attn".into(),
            input: Shape { h: 1, w: 64, c: 512 },
            layers: vec![Layer {
                name: "mha".into(),
                kind: LayerKind::Attention { heads: 8, dmodel: 512, dk: 64, seq: 64 },
                relu: false,
            }],
        };
        let m = n.macs().unwrap()[0];
        assert!(m > 0);
        // projections dominate at short sequence lengths
        assert!(m as usize > 4 * 64 * 512 * 512);
    }
}
