//! Network shape library and graph format (paper §4.2, 4.4.3–4.4.4).
//!
//! The compiler consumes an abstract layer graph — either imported from
//! the python-side JSON bundle (trained weights) or synthesized from the
//! shape library below (the paper's evaluation networks: the Figs. 13–15
//! experiments are cycle-count experiments that depend only on layer
//! geometry and sparsity, not on trained values).

pub mod graph;
pub mod passes;
pub mod zoo;

pub use graph::{Layer, LayerKind, Network};
pub use passes::{normalize, LayerFate, Normalized};
pub use zoo::{alexnet, lenet_300_100, resnet50, transformer_mha, vgg19, vgg_nano};
