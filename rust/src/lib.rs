//! # APU — Accelerator Processing Unit framework
//!
//! Reproduction of *"Tuning Algorithms and Generators for Efficient Edge
//! Inference"* (Naous et al., 2019) as a three-layer Rust + JAX + Pallas
//! stack. See README.md for the quickstart and ROADMAP.md for the system
//! inventory and experiment index.
//!
//! Layer map:
//! * **L3 (this crate)** — the co-design framework: structured-pruning
//!   decomposition, routing scheduler, hardware generator, cycle-accurate
//!   simulator, network compiler, baselines, and the edge-serving
//!   coordinator. The coordinator scales out via `coordinator::fleet`:
//!   N shard workers (each owning its own engine + batcher) behind a
//!   pluggable dispatcher (`coordinator::dispatch` — round-robin,
//!   least-outstanding, join-shortest-queue) with bounded per-shard
//!   queues (admission control) and SLO reporting (`coordinator::slo`:
//!   p50/p95/p99, queue depth, rejection rate). Serving is model-keyed:
//!   a `coordinator::catalog::ModelCatalog` resolves named models into
//!   shared programs/plans (one plan build per model process-wide via
//!   the `sim::plan` cache), and fleets route per-model shard groups.
//!   The single-engine `Server` is the 1-shard special case of the fleet.
//! * **L2/L1 (python/, build-time only)** — JAX training with mask
//!   molding + INT4 QAT, and the Pallas block-diagonal FC kernel, AOT
//!   lowered to HLO text artifacts.
//! * **runtime** — loads those artifacts via the PJRT CPU client (the
//!   golden numeric model the simulator is validated against).

pub mod baselines;
pub mod compiler;
pub mod coordinator;
pub mod figures;
pub mod generator;
pub mod hwmodel;
pub mod isa;
pub mod nn;
pub mod obs;
pub mod pruning;
pub mod routing;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;
