//! EIE-style unstructured-pruning accelerator model (Han et al., ISCA'16
//! — the paper's [13] comparator).
//!
//! EIE stores pruned weights in CSC form and processes one nonzero MAC
//! per lane per cycle, broadcasting one input activation at a time. Its
//! documented costs, which this model captures:
//!
//! * **pointer overhead** — every nonzero carries a relative index; the
//!   weight+index pair shares the lane's SRAM port (the paper's "added
//!   pointer overhead to account for the irregularity");
//! * **load imbalance** — nonzeros per column vary randomly, so lanes
//!   idle at column boundaries (EIE reports ~30% FIFO-starved cycles
//!   without deep queues);
//! * **activation sparsity** — EIE skips zero input activations (a real
//!   advantage the structured design does not claim; Fig. 15's caption
//!   notes the comparison credits it to EIE);
//! * **weight streaming** — layers over the SRAM budget stream weight+
//!   index pairs from DRAM over the shared bus.

use anyhow::Result;

/// EIE machine parameters.
#[derive(Debug, Clone)]
pub struct EieModel {
    /// Processing lanes (PEs in EIE terms), 1 nonzero MAC/cycle each.
    pub lanes: usize,
    /// Unstructured weight density after pruning (paper: ~10%).
    pub weight_density: f64,
    /// Input activation density (ReLU networks: ~30–40% nonzero).
    pub act_density: f64,
    /// Cycle inflation from per-column load imbalance.
    pub imbalance: f64,
    /// Cycle inflation from pointer/index fetch sharing the SRAM port.
    pub pointer_overhead: f64,
    /// Bits per stored nonzero (4 b weight + 4 b relative index).
    pub bits_per_nnz: u64,
    /// On-chip SRAM budget for weights, bits.
    pub sram_bits: u64,
    /// DRAM bus, bits per cycle.
    pub dma_bits_per_cycle: u64,
}

impl Default for EieModel {
    fn default() -> Self {
        EieModel {
            lanes: 9, // matched to the Fig. 15 setup (9 PEs both sides)
            weight_density: 0.10,
            act_density: 0.35,
            imbalance: 1.25,
            pointer_overhead: 1.30,
            bits_per_nnz: 8,
            sram_bits: 9 * 513 * 513 * 4, // same budget as the APU instance
            dma_bits_per_cycle: 64,
        }
    }
}

/// Per-layer EIE cost.
#[derive(Debug, Clone)]
pub struct EieLayerCost {
    pub nnz: u64,
    pub compute_cycles: u64,
    pub stream_cycles: u64,
}

impl EieLayerCost {
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.stream_cycles
    }
}

impl EieModel {
    /// Cost a sparse mat-vec of a `dout × din` layer.
    pub fn fc_cost(&self, dout: usize, din: usize) -> Result<EieLayerCost> {
        let macs = (dout as u64) * (din as u64);
        let nnz = (macs as f64 * self.weight_density).ceil() as u64;
        // Lanes process nonzeros of the *active* (nonzero) input columns.
        let effective = (nnz as f64 * self.act_density).ceil() as u64;
        let compute = ((effective as f64 / self.lanes as f64) * self.imbalance * self.pointer_overhead)
            .ceil() as u64;
        let weight_bits = nnz * self.bits_per_nnz;
        let stream = if weight_bits > self.sram_bits {
            weight_bits.div_ceil(self.dma_bits_per_cycle)
        } else {
            0
        };
        Ok(EieLayerCost { nnz, compute_cycles: compute, stream_cycles: stream })
    }

    /// Cost a convolution lowered to im2col mat-vecs. EIE is an FC engine
    /// with no conv line buffer: every output position's input window is
    /// re-materialized through the activation queue, so the im2col
    /// expansion (positions × kvol values) crosses the memory interface —
    /// the §5 point that unstructured engines lose the convolution's data
    /// reuse.
    pub fn conv_cost(&self, positions: usize, cout: usize, kvol: usize) -> Result<EieLayerCost> {
        let macs = positions as u64 * cout as u64 * kvol as u64;
        let nnz = (macs as f64 * self.weight_density).ceil() as u64;
        let effective = (nnz as f64 * self.act_density).ceil() as u64;
        let mac_cycles = ((effective as f64 / self.lanes as f64) * self.imbalance * self.pointer_overhead)
            .ceil() as u64;
        // im2col activation traffic over the shared bus (4-bit values)
        let im2col_bits = positions as u64 * kvol as u64 * 4;
        let act_cycles = im2col_bits.div_ceil(self.dma_bits_per_cycle);
        let compute = mac_cycles + act_cycles;
        // weights are reused across positions; only the kernel is stored
        let weight_bits = (cout as u64 * kvol as u64) * self.bits_per_nnz;
        let stream = if weight_bits > self.sram_bits {
            weight_bits.div_ceil(self.dma_bits_per_cycle)
        } else {
            0
        };
        Ok(EieLayerCost { nnz, compute_cycles: compute, stream_cycles: stream })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_scales_with_nnz() {
        let m = EieModel::default();
        let small = m.fc_cost(1024, 1024).unwrap();
        let big = m.fc_cost(4096, 4096).unwrap();
        assert!((big.nnz as f64 / small.nnz as f64 - 16.0).abs() < 0.01);
        assert!(big.compute_cycles > small.compute_cycles * 12);
    }

    #[test]
    fn overheads_inflate_cycles() {
        let base = EieModel { imbalance: 1.0, pointer_overhead: 1.0, ..Default::default() };
        let real = EieModel::default();
        let b = base.fc_cost(4096, 4096).unwrap().compute_cycles;
        let r = real.fc_cost(4096, 4096).unwrap().compute_cycles;
        let ratio = r as f64 / b as f64;
        assert!((ratio - 1.25 * 1.30).abs() < 0.01, "overhead ratio {ratio}");
    }

    #[test]
    fn big_layers_stream_with_pointer_tax() {
        let m = EieModel::default();
        // VGG FC6: 25088×4096 @10% = 10.3M nnz × 8 b = 82 Mb >> 9.4 Mb
        let c = m.fc_cost(4096, 25088).unwrap();
        assert!(c.stream_cycles > 0);
        // the 8b-per-nnz pointer tax: streaming is 2× a dense-block design
        // holding the same nonzeros at 4 b each
        let dense_equivalent = (c.nnz * 4).div_ceil(64);
        assert!((c.stream_cycles as f64 / dense_equivalent as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn act_sparsity_helps_eie() {
        let dense_acts = EieModel { act_density: 1.0, ..Default::default() };
        let sparse_acts = EieModel::default();
        assert!(
            sparse_acts.fc_cost(4096, 4096).unwrap().compute_cycles
                < dense_acts.fc_cost(4096, 4096).unwrap().compute_cycles / 2
        );
    }

    #[test]
    fn conv_weights_reused() {
        let m = EieModel::default();
        let c = m.conv_cost(56 * 56, 256, 9 * 256).unwrap();
        assert_eq!(c.stream_cycles, 0); // kernel fits on chip
        assert!(c.compute_cycles > 0);
    }
}
