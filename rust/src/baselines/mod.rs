//! Baseline accelerator and processor models the paper compares against
//! (§5, Fig. 15, Figs. 13–14 speedup denominators).

pub mod dense;
pub mod eie;

pub use dense::{cpu_gpu_ratios, DenseSystolicModel};
pub use eie::EieModel;
