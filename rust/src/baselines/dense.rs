//! Dense-systolic (TPU-like) and general-purpose-processor baselines
//! (paper §5's comparison context).

/// A TPU1-style weight-stationary systolic array running the *dense*
/// (unpruned) layer: it cannot exploit sparsity, so it pays for every MAC,
/// but achieves near-perfect MAC/cycle utilization on large matrices.
#[derive(Debug, Clone)]
pub struct DenseSystolicModel {
    /// Systolic array dimensions (TPU1: 256×256; we default to a
    /// same-area-class 128×128 at INT8).
    pub rows: usize,
    pub cols: usize,
    /// Pipeline fill/drain overhead per tile pass.
    pub fill_overhead: f64,
    /// DRAM bus for weight tiles, bits/cycle.
    pub dma_bits_per_cycle: u64,
    pub weight_bits: u32,
    pub sram_bits: u64,
}

impl Default for DenseSystolicModel {
    fn default() -> Self {
        DenseSystolicModel {
            rows: 128,
            cols: 128,
            fill_overhead: 1.1,
            dma_bits_per_cycle: 64,
            weight_bits: 8,
            sram_bits: 24 * 1024 * 1024 * 8, // 24 MB unified buffer
        }
    }
}

impl DenseSystolicModel {
    /// Cycles for a dense `dout × din` mat-vec (batch 1 — the edge case
    /// the paper targets; systolic arrays hate batch 1).
    pub fn fc_cycles(&self, dout: usize, din: usize) -> u64 {
        let tiles_r = dout.div_ceil(self.rows) as u64;
        let tiles_c = din.div_ceil(self.cols) as u64;
        // batch-1 mat-vec: each tile pass streams `cols` activations and
        // produces `rows` partials; pipeline depth dominates.
        let per_tile = (self.rows + self.cols) as f64 * self.fill_overhead;
        let compute = (tiles_r * tiles_c) as f64 * per_tile;
        let weight_bits = (dout as u64) * (din as u64) * self.weight_bits as u64;
        let stream = if weight_bits > self.sram_bits {
            weight_bits.div_ceil(self.dma_bits_per_cycle)
        } else {
            0
        };
        compute.ceil() as u64 + stream
    }
}

/// The paper's quoted general-purpose-processor ratios (§5): structured
/// pruning reaches ~4× on GPU where unstructured (Scalpel/cuSPARSE)
/// reaches ~1.25×, and EIE reports 5.12× over GPU dense. Returned as
/// `(name, speedup_over_dense_gpu)` rows for the related-work table;
/// these are literature constants, not measurements.
pub fn cpu_gpu_ratios() -> Vec<(&'static str, f64)> {
    vec![
        ("gpu-dense", 1.0),
        ("gpu-cusparse-unstructured (Scalpel)", 1.25),
        ("gpu-structured-pruning [18,16]", 4.0),
        ("eie-asic [13]", 5.12),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_cycles_grow_with_size() {
        let m = DenseSystolicModel::default();
        assert!(m.fc_cycles(4096, 4096) > m.fc_cycles(1024, 1024) * 8);
    }

    #[test]
    fn dense_pays_for_zeros() {
        // The systolic baseline's cycles are ~independent of sparsity —
        // that's the paper's §5 point about TPU-style dense designs.
        let m = DenseSystolicModel::default();
        let dense = m.fc_cycles(4096, 9216);
        let eie = crate::baselines::EieModel::default().fc_cost(4096, 9216).unwrap();
        // at 10% density a sparsity-aware design does far less work
        assert!(eie.compute_cycles < dense);
    }

    #[test]
    fn quoted_ratios_ordered() {
        let r = cpu_gpu_ratios();
        assert!(r[1].1 < r[2].1 && r[2].1 < r[3].1);
    }
}
